//! CI smoke for the durable path of the engine facade, two phases:
//!
//! 1. ingest through `EngineBuilder` into a tmpdir store, "kill" the
//!    session mid-write (simulated torn WAL tail), reopen through the
//!    builder (recovery), query, and verify bit-identity against the
//!    in-memory reference;
//! 2. the **group-commit crash window**: ingest through the pipelined
//!    async path (appends ride group commits), kill after the last ack
//!    with a torn half-written group appended to the WAL — i.e. a crash
//!    between a group's `write` and its `fsync` — and verify every
//!    acked batch survives recovery while the unacked tail vanishes
//!    without double-counting;
//! 3. a **seeded chaos crash**: crash the whole VFS (torn write + every
//!    later op failing, via `FaultVfs`) at one seeded operation of the
//!    workload, recover over the real filesystem, and verify the acked
//!    batch prefix is bit-identical on all four query execution tiers.
//!    The seed comes from `CHAOS_SEED` (printed; set it to replay).
//!
//! Exits nonzero on any divergence — wired into `ci.sh` as the store
//! gate (`ci.sh --chaos` re-runs it under many random seeds).

use std::fs;
use std::path::Path;
use std::process::ExitCode;

use sotb_bic::bic::{BicConfig, BicCore, Bitmap, BitmapIndex, Query};
use sotb_bic::coordinator::{ContentDist, WorkloadGen};
use sotb_bic::engine::{Engine, ExecPath, Schema};
use sotb_bic::store::vfs::FaultVfs;
use sotb_bic::substrate::rng::Xoshiro256;

/// Golden-model replay: index every batch with `keys` and concatenate.
fn reference(
    cfg: BicConfig,
    keys: &[i32],
    batch_records: &[Vec<Vec<i32>>],
) -> BitmapIndex {
    let mut core = BicCore::new(cfg);
    let n = batch_records.len() * cfg.n_records;
    let mut rows = vec![Bitmap::zeros(n); cfg.m_keys];
    for (b, records) in batch_records.iter().enumerate() {
        let bi = core.index(records, keys);
        for (a, row) in rows.iter_mut().enumerate() {
            row.or_at(bi.row(a), b * cfg.n_records);
        }
    }
    BitmapIndex::from_rows(rows)
}

fn main() -> ExitCode {
    let cfg = BicConfig { n_records: 48, w_words: 8, m_keys: 8 };
    let keys: Vec<i32> = vec![3, 7, 19, 42, 101, 160, 201, 250];
    let dist = ContentDist::Clustered { spread: 12 };
    let seed = 0x5770_4E5D;
    let total_batches = 11usize;
    let dir = std::env::temp_dir()
        .join(format!("bic-store-smoke-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);

    let build_engine = |dir: &Path, flush_batches: usize| {
        Engine::builder(
            Schema::single("byte", keys.clone()).expect("valid schema"),
        )
        .batch_records(cfg.n_records)
        .record_words(cfg.w_words)
        .durable(dir)
        .flush_batches(flush_batches)
        .build()
    };

    // ---- Phase 1: torn-tail kill on the synchronous path. ----
    // 11 batches, flush every 4 -> 2 segments + 3 in the WAL.
    let engine = build_engine(&dir, 4).expect("create engine");
    let mut wg = WorkloadGen::new(cfg, dist, seed);
    let batch_records: Vec<Vec<Vec<i32>>> =
        (0..total_batches).map(|i| wg.batch_at(i as f64).records).collect();
    for records in &batch_records {
        let receipt = engine.ingest(records).expect("ingest");
        assert!(receipt.durable, "durable engine must ack through the WAL");
    }
    let stats = engine.stats();
    println!(
        "store-smoke: ingested {total_batches} batches -> {} segments + {} \
         memtable batches, {} segment bytes",
        stats.segments, stats.memtable_batches, stats.segment_bytes_written
    );

    // Kill: drop the handle without close(), then tear the WAL tail so
    // the last acknowledged batch's record is cut mid-payload.
    drop(engine);
    let wal_path = dir.join("wal-00000002.log");
    let wal = fs::read(&wal_path).expect("wal exists");
    let torn = wal.len() - 5;
    fs::write(&wal_path, &wal[..torn]).expect("tear wal");
    println!("store-smoke: tore the WAL at byte {torn} of {}", wal.len());

    // Reopen through the builder: always the recovery path. The torn
    // record's batch (the last one) is gone; every durably-complete
    // record survives.
    let engine = build_engine(&dir, 4).expect("recover engine");
    let stats = engine.stats();
    println!(
        "store-smoke: recovered {} segments + {} memtable batches",
        stats.segments, stats.memtable_batches
    );
    if stats.memtable_batches != 2 {
        eprintln!(
            "store-smoke: FAIL expected 2 surviving memtable batches, got {}",
            stats.memtable_batches
        );
        return ExitCode::FAILURE;
    }
    let survived = 4 * 2 + stats.memtable_batches;

    // Verify: bit-identical to the reference over the surviving prefix,
    // and planned queries agree with the uncompressed eval.
    let expect = reference(cfg, &keys, &batch_records[..survived]);
    if engine.snapshot().to_index() != expect {
        eprintln!("store-smoke: FAIL recovered index diverges from reference");
        return ExitCode::FAILURE;
    }
    let queries = [
        Query::attr(1).and(Query::attr(3)).and(Query::attr(5).not()),
        Query::attr(0).or(Query::attr(7)),
        Query::attr(2).not(),
    ];
    for (i, q) in queries.iter().enumerate() {
        let got = engine.query(q).expect("engine query");
        let want = q.eval(&expect).expect("reference eval");
        if got != want {
            eprintln!("store-smoke: FAIL query {i} diverges");
            return ExitCode::FAILURE;
        }
        println!(
            "store-smoke: query {i} matches ({} of {} objects)",
            got.count_ones(),
            expect.num_objects()
        );
    }
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
    println!("store-smoke: phase 1 OK (ingest -> kill -> recover -> query)");

    // ---- Phase 2: the group-commit crash window. ----
    // Async-pipelined ingest (appends ride group commits), no
    // auto-flush so every acked batch lives in WAL generation 0.
    let dir2 = std::env::temp_dir()
        .join(format!("bic-store-smoke-gc-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir2);
    let acked = 7usize;
    let engine = build_engine(&dir2, 0).expect("create gc engine");
    let tickets = engine
        .ingest_batches_async(batch_records[..acked].to_vec())
        .expect("submit");
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().expect("receipt");
        if !r.durable || r.batch != i as u64 {
            eprintln!(
                "store-smoke: FAIL async receipt {i} (batch {}, durable {})",
                r.batch, r.durable
            );
            return ExitCode::FAILURE;
        }
    }
    println!("store-smoke: async-acked {acked} batches through group commit");

    // Kill between a group's append and its fsync: drop the handle,
    // then append a half-written record — bytes the next group's
    // `write` put in the file before the crash stole its `fsync`. No
    // ticket for it ever acknowledged.
    drop(engine);
    let wal2 = dir2.join("wal-00000000.log");
    let mut bytes = fs::read(&wal2).expect("gc wal exists");
    let acked_len = bytes.len();
    bytes.extend_from_slice(&4096u32.to_le_bytes()); // claimed length
    bytes.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes()); // bogus crc
    bytes.extend_from_slice(&[0x5A; 7]); // 7 of the claimed 4096 bytes
    fs::write(&wal2, &bytes).expect("append torn group");
    println!(
        "store-smoke: appended a torn group tail ({} -> {} bytes)",
        acked_len,
        bytes.len()
    );

    // Recovery: every acked batch survives, the torn group vanishes,
    // nothing double-counts.
    let engine = build_engine(&dir2, 0).expect("recover gc engine");
    let stats = engine.stats();
    if stats.memtable_batches != acked || stats.segments != 0 {
        eprintln!(
            "store-smoke: FAIL expected {acked} memtable batches + 0 \
             segments, got {} + {}",
            stats.memtable_batches, stats.segments
        );
        return ExitCode::FAILURE;
    }
    if stats.objects != acked * cfg.n_records {
        eprintln!(
            "store-smoke: FAIL expected {} objects, got {}",
            acked * cfg.n_records,
            stats.objects
        );
        return ExitCode::FAILURE;
    }
    let expect = reference(cfg, &keys, &batch_records[..acked]);
    if engine.snapshot().to_index() != expect {
        eprintln!(
            "store-smoke: FAIL group-commit recovery diverges from the \
             acked prefix"
        );
        return ExitCode::FAILURE;
    }
    for (i, q) in queries.iter().enumerate() {
        let got = engine.query(q).expect("engine query");
        if got != q.eval(&expect).expect("reference eval") {
            eprintln!("store-smoke: FAIL gc query {i} diverges");
            return ExitCode::FAILURE;
        }
    }
    engine.close().expect("close gc engine");
    let _ = fs::remove_dir_all(&dir2);
    println!(
        "store-smoke: phase 2 OK (async acks survive the group-commit \
         crash window)"
    );

    // ---- Phase 3: seeded chaos crash at one random VFS operation. ----
    let chaos_seed: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC405_0A05);
    println!("store-smoke: CHAOS_SEED={chaos_seed} (set the env var to replay)");
    let dir3 = std::env::temp_dir()
        .join(format!("bic-store-smoke-chaos-{}", std::process::id()));
    let chaos_batches = &batch_records[..8];
    let build_chaos = |vfs: Option<std::sync::Arc<FaultVfs>>| {
        let mut b = Engine::builder(
            Schema::single("byte", keys.clone()).expect("valid schema"),
        )
        .batch_records(cfg.n_records)
        .record_words(cfg.w_words)
        .durable(&dir3)
        .flush_batches(3);
        if let Some(v) = vfs {
            b = b.vfs(v);
        }
        b.build()
    };

    // Measure the workload's op count fault-free, then pick the crash
    // point from the seed.
    let _ = fs::remove_dir_all(&dir3);
    let probe = FaultVfs::counting(chaos_seed);
    let engine =
        build_chaos(Some(std::sync::Arc::clone(&probe))).expect("measure");
    for records in chaos_batches {
        engine.ingest(records).expect("measure ingest");
    }
    engine.close().expect("measure close");
    let total = probe.ops();
    let crash_op = Xoshiro256::seeded(chaos_seed).next_below(total);
    println!(
        "store-smoke: chaos crash at vfs op {crash_op} of {total} \
         (create -> ingest x{} -> close)",
        chaos_batches.len()
    );

    // Crashed run: count the batches that acknowledged before death.
    let _ = fs::remove_dir_all(&dir3);
    let mut acked = 0usize;
    if let Ok(engine) = build_chaos(Some(FaultVfs::crash_at(chaos_seed, crash_op)))
    {
        for records in chaos_batches {
            match engine.ingest(records) {
                Ok(_) => acked += 1,
                Err(_) => break, // the vfs is dead from here on
            }
        }
        let _ = engine.close();
    }
    println!("store-smoke: {acked} of {} batches acked", chaos_batches.len());

    // Recover over the real filesystem and hold the durability line:
    // a whole number of batches, at least every acked one, bit-identical
    // to the reference prefix on every execution tier.
    let engine = match build_chaos(None) {
        Ok(e) => e,
        Err(e) => {
            eprintln!(
                "store-smoke: FAIL CHAOS_SEED={chaos_seed} recovery: {e}"
            );
            return ExitCode::FAILURE;
        }
    };
    let objects = engine.num_objects();
    if objects % cfg.n_records != 0 {
        eprintln!(
            "store-smoke: FAIL CHAOS_SEED={chaos_seed}: {objects} objects \
             is a partial batch"
        );
        return ExitCode::FAILURE;
    }
    let recovered = objects / cfg.n_records;
    if recovered < acked || recovered > chaos_batches.len() {
        eprintln!(
            "store-smoke: FAIL CHAOS_SEED={chaos_seed}: recovered \
             {recovered} batches, acked {acked}, submitted {}",
            chaos_batches.len()
        );
        return ExitCode::FAILURE;
    }
    let expect = reference(cfg, &keys, &chaos_batches[..recovered]);
    if engine.snapshot().to_index() != expect {
        eprintln!(
            "store-smoke: FAIL CHAOS_SEED={chaos_seed}: recovered index \
             diverges from the {recovered}-batch reference"
        );
        return ExitCode::FAILURE;
    }
    for (i, q) in queries.iter().enumerate() {
        let want = q.eval(&expect).expect("reference eval");
        for path in ExecPath::ALL {
            match engine.query_via(q, path) {
                Ok(got) if got == want => {}
                Ok(_) => {
                    eprintln!(
                        "store-smoke: FAIL CHAOS_SEED={chaos_seed}: query \
                         {i} via {path:?} diverges"
                    );
                    return ExitCode::FAILURE;
                }
                Err(e) => {
                    eprintln!(
                        "store-smoke: FAIL CHAOS_SEED={chaos_seed}: query \
                         {i} via {path:?}: {e}"
                    );
                    return ExitCode::FAILURE;
                }
            }
        }
    }
    engine.close().expect("close chaos engine");
    let _ = fs::remove_dir_all(&dir3);
    println!(
        "store-smoke: phase 3 OK (crash at op {crash_op}: acked prefix \
         held on all tiers)"
    );
    println!("store-smoke: OK");
    ExitCode::SUCCESS
}
