//! `bench_gate` — the CI bench-regression comparator.
//!
//! Reads the committed `BENCH_baseline.json` and one or more freshly
//! emitted `BENCH_*.json` files (hotpath + compression, written by
//! `cargo bench` under `BENCH_SMOKE=1`), matches cases by name, and fails
//! (exit 1) when any tracked kernel's mean time regresses more than the
//! tolerance (default 25%, `--tolerance` / `BENCH_GATE_TOLERANCE` / the
//! baseline's own `tolerance` field).
//!
//! Baselines carry a `calibrated` flag: while it is `false` (a
//! placeholder committed before the first pinned-host run), the gate
//! reports every comparison but exits 0, so a fresh repo is not red on
//! invented numbers. Calibrate and enforce with:
//!
//! ```text
//! cd rust && BENCH_SMOKE=1 cargo bench --bench hotpath \
//!         && BENCH_SMOKE=1 cargo bench --bench ablations \
//!         && cargo run --release --bin bench_gate -- \
//!            --update BENCH_baseline.json BENCH_hotpath.json BENCH_compression.json
//! ```
//!
//! `--update` rewrites the baseline from the current files and sets
//! `calibrated: true`.

use std::collections::BTreeMap;
use std::process::ExitCode;

use sotb_bic::substrate::json::Json;

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path}: {e}"))
}

/// Flatten every `{"name": ..., "mean_s": ...}` object found in any
/// top-level array of the document — matches the layout of every
/// `BENCH_*.json` this repo writes (and of the baseline's `cases`).
fn means(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Json::Obj(map) = doc {
        for v in map.values() {
            let Some(cases) = v.as_arr() else { continue };
            for c in cases {
                if let (Some(name), Some(mean)) = (
                    c.get("name").and_then(Json::as_str),
                    c.get("mean_s").and_then(Json::as_f64),
                ) {
                    out.push((name.to_string(), mean));
                }
            }
        }
    }
    out
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: bench_gate [--tolerance X] <baseline.json> <current.json>...\n\
         \u{20}      bench_gate --update <baseline.json> <current.json>..."
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let mut update = false;
    let mut tolerance: Option<f64> = std::env::var("BENCH_GATE_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok());
    while let Some(first) = args.first().cloned() {
        match first.as_str() {
            "--update" => {
                update = true;
                args.remove(0);
            }
            "--tolerance" => {
                args.remove(0);
                if args.is_empty() {
                    return usage();
                }
                match args.remove(0).parse() {
                    Ok(t) => tolerance = Some(t),
                    Err(_) => return usage(),
                }
            }
            _ => break,
        }
    }
    if args.len() < 2 {
        return usage();
    }
    let baseline_path = args.remove(0);

    let mut current: BTreeMap<String, f64> = BTreeMap::new();
    for path in &args {
        let doc = match load(path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench_gate: {e}");
                return ExitCode::FAILURE;
            }
        };
        for (name, mean) in means(&doc) {
            current.insert(name, mean);
        }
    }
    if current.is_empty() {
        eprintln!("bench_gate: no cases found in {args:?}");
        return ExitCode::FAILURE;
    }

    if update {
        let tol = tolerance.unwrap_or(0.25);
        let cases: Vec<Json> = current
            .iter()
            .map(|(name, mean)| {
                Json::obj([
                    ("name", name.as_str().into()),
                    ("mean_s", (*mean).into()),
                ])
            })
            .collect();
        let doc = Json::obj([
            ("calibrated", true.into()),
            ("tolerance", tol.into()),
            ("cases", Json::Arr(cases)),
        ]);
        return match std::fs::write(&baseline_path, doc.render() + "\n") {
            Ok(()) => {
                println!(
                    "bench_gate: wrote {} calibrated cases to {baseline_path}",
                    current.len()
                );
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("bench_gate: {baseline_path}: {e}");
                ExitCode::FAILURE
            }
        };
    }

    let baseline_doc = match load(&baseline_path) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            return ExitCode::FAILURE;
        }
    };
    // Absent flag means an already-calibrated baseline: enforce.
    let calibrated = baseline_doc
        .get("calibrated")
        .and_then(Json::as_bool)
        .unwrap_or(true);
    let tol = tolerance
        .or_else(|| baseline_doc.get("tolerance").and_then(Json::as_f64))
        .unwrap_or(0.25);

    let mut regressions = Vec::new();
    let mut compared = 0usize;
    let mut missing = 0usize;
    for (name, base) in means(&baseline_doc) {
        let Some(&cur) = current.get(&name) else {
            // Smoke runs legitimately skip cases (no PJRT artifacts, a
            // single-core host): warn, do not fail.
            println!("  missing  {name} (baseline {base:.3e} s)");
            missing += 1;
            continue;
        };
        compared += 1;
        let ratio = if base > 0.0 { cur / base } else { 1.0 };
        let verdict = if ratio > 1.0 + tol {
            regressions.push((name.clone(), base, cur, ratio));
            "REGRESSED"
        } else if ratio < 1.0 - tol {
            "improved"
        } else {
            "ok"
        };
        println!("  {verdict:<9} {name}: {base:.3e} -> {cur:.3e} s ({ratio:.2}x)");
    }
    println!(
        "bench_gate: {compared} compared, {missing} missing, {} regressed \
         (tolerance {:.0}%)",
        regressions.len(),
        tol * 100.0
    );
    if compared == 0 {
        eprintln!("bench_gate: baseline and current share no cases");
        return ExitCode::FAILURE;
    }
    if !regressions.is_empty() {
        for (name, base, cur, ratio) in &regressions {
            eprintln!(
                "bench_gate: REGRESSION {name}: {base:.3e} -> {cur:.3e} s \
                 ({ratio:.2}x > {:.2}x)",
                1.0 + tol
            );
        }
        if calibrated {
            return ExitCode::FAILURE;
        }
        println!(
            "bench_gate: baseline is uncalibrated (calibrated: false) — \
             reporting only; run with --update on a pinned host to enforce"
        );
    }
    ExitCode::SUCCESS
}
