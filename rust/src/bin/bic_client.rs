//! `bic_client` — line-protocol driver for `bic_server`, used by
//! `ci.sh --serve` and by hand.
//!
//! ```text
//! bic_client ping      --addr HOST:PORT
//! bic_client smoke     --addr HOST:PORT [--tenant NAME]
//! bic_client verify    --addr HOST:PORT [--tenant NAME]
//! bic_client hammer    --addr HOST:PORT [--tenant NAME]
//!                      [--workers N] [--iters K] [--telemetry]
//! bic_client obscheck  --addr HOST:PORT [--tenant NAME]
//! bic_client aggregate --addr HOST:PORT [--tenant NAME] [--col COL]
//!                      [--agg count|sum|min|max] [--lo V --hi V]
//! bic_client topk      --addr HOST:PORT [--tenant NAME] [--col COL]
//!                      [--k N] [--lo V --hi V]
//! ```
//!
//! `smoke` creates a tenant and ingests a fixed deterministic data set;
//! `verify` re-queries that data set and checks the exact counts —
//! running `smoke`, killing the server, restarting it, and running
//! `verify` pins crash recovery plus lazy tenant reopen end to end.
//! `hammer` drives N concurrent ingest+query workers over one socket
//! each and reports per-worker ops/sec *and latency percentiles*
//! (p50/p99/max, measured client-side into a mergeable histogram;
//! `busy` responses are retried after backoff and counted, never
//! fatal). With `--telemetry` the tenant is created collecting
//! telemetry, so the server-side quantiles are populated too.
//! `obscheck` asserts the observability surface end to end: `metrics`
//! exposes nonzero per-tenant quantiles and the Prometheus text,
//! `explain` round-trips with `analyze`, `slowlog`/`trace` answer, and
//! — after driving one `aggregate` and one `topk` — the bit-sliced
//! kernel channels (`telemetry.aggregate`/`telemetry.topk`) populate.
//! `aggregate` and `topk` issue one ad-hoc command against an existing
//! tenant, with an optional `between [lo, hi]` filter.

use std::process::ExitCode;

use sotb_bic::bic::clock;
use sotb_bic::obs::{HistSnapshot, Histogram};
use sotb_bic::server::client::Client;
use sotb_bic::server::protocol::{response_error_code, response_ok};
use sotb_bic::substrate::cli::Args;
use sotb_bic::substrate::json::Json;

/// Key universe for the smoke tenant's single column.
const KEYS: [i32; 8] = [1, 2, 3, 4, 5, 6, 7, 8];
/// Batches in the smoke data set.
const SMOKE_BATCHES: usize = 6;
/// Records per smoke batch.
const SMOKE_RECORDS: usize = 4;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("bic_client: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<(), String> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw)?;
    let addr = args.require("addr")?.to_string();
    let tenant = args.get("tenant").unwrap_or("smoke").to_string();
    match args.subcommand.as_deref() {
        Some("ping") => ping(&addr),
        Some("smoke") => smoke(&addr, &tenant),
        Some("verify") => verify(&addr, &tenant),
        Some("hammer") => {
            let workers = args.get_parsed("workers", 4usize)?;
            let iters = args.get_parsed("iters", 32usize)?;
            let telemetry = args.get("telemetry").is_some();
            hammer(&addr, &tenant, workers, iters, telemetry)
        }
        Some("obscheck") => obscheck(&addr, &tenant),
        Some("aggregate") => {
            let col = args.get("col").unwrap_or("k").to_string();
            let agg = args.get("agg").unwrap_or("sum").to_string();
            aggregate(&addr, &tenant, &col, &agg, range_filter(&args)?)
        }
        Some("topk") => {
            let col = args.get("col").unwrap_or("k").to_string();
            let k = args.get_parsed("k", 3usize)?;
            topk(&addr, &tenant, &col, k, range_filter(&args)?)
        }
        other => Err(format!(
            "unknown subcommand {other:?}; expected \
             ping|smoke|verify|hammer|obscheck|aggregate|topk"
        )),
    }
}

fn connect(addr: &str) -> Result<Client, String> {
    Client::connect(addr).map_err(|e| format!("connect {addr}: {e}"))
}

/// Expect an `ok` response; surface `{code, what, detail}` otherwise.
fn expect_ok(what: &str, resp: Json) -> Result<Json, String> {
    if response_ok(&resp) {
        return Ok(resp);
    }
    let err = resp.get("error");
    let field = |k| {
        err.and_then(|e| e.get(k))
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string()
    };
    Err(format!(
        "{what}: server error code={} what={} detail={}",
        field("code"),
        field("what"),
        field("detail")
    ))
}

fn count_of(resp: &Json) -> Option<f64> {
    resp.get("count").and_then(Json::as_f64)
}

/// The fixed smoke data set: `SMOKE_BATCHES` batches of `SMOKE_RECORDS`
/// one-word records cycling through `KEYS`, so every key matches
/// exactly `SMOKE_BATCHES * SMOKE_RECORDS / KEYS.len()` records.
fn smoke_batch(i: usize) -> Vec<Vec<i32>> {
    (0..SMOKE_RECORDS)
        .map(|j| vec![KEYS[(i * SMOKE_RECORDS + j) % KEYS.len()]])
        .collect()
}

fn expected_per_key() -> f64 {
    (SMOKE_BATCHES * SMOKE_RECORDS / KEYS.len()) as f64
}

fn eq_predicate(key: i32) -> Json {
    Json::obj([("col", "k".into()), ("eq", key.into())])
}

/// `--lo V --hi V` into a `between` filter document (both or neither).
fn range_filter(
    args: &sotb_bic::substrate::cli::Args,
) -> Result<Option<Json>, String> {
    match (args.get("lo"), args.get("hi")) {
        (None, None) => Ok(None),
        (Some(_), None) | (None, Some(_)) => {
            Err("--lo and --hi must be given together".into())
        }
        (Some(_), Some(_)) => {
            let lo = args.get_parsed("lo", 0i32)?;
            let hi = args.get_parsed("hi", 0i32)?;
            let col = args.get("col").unwrap_or("k");
            Ok(Some(Json::obj([
                ("col", col.into()),
                ("between", Json::Arr(vec![lo.into(), hi.into()])),
            ])))
        }
    }
}

fn aggregate(
    addr: &str,
    tenant: &str,
    col: &str,
    agg: &str,
    filter: Option<Json>,
) -> Result<(), String> {
    let mut c = connect(addr)?;
    let resp = c
        .aggregate(tenant, col, agg, filter.as_ref())
        .map_err(|e| format!("aggregate: {e}"))?;
    let resp = expect_ok("aggregate", resp)?;
    let rows = resp.get("rows").and_then(Json::as_f64).unwrap_or(0.0);
    let value = resp
        .get("value")
        .and_then(Json::as_f64)
        .map_or("null".to_string(), |v| format!("{v}"));
    println!("AGGREGATE OK tenant={tenant} col={col} agg={agg} rows={rows} value={value}");
    Ok(())
}

fn topk(
    addr: &str,
    tenant: &str,
    col: &str,
    k: usize,
    filter: Option<Json>,
) -> Result<(), String> {
    let mut c = connect(addr)?;
    let resp = c
        .topk(tenant, col, k, filter.as_ref())
        .map_err(|e| format!("topk: {e}"))?;
    let resp = expect_ok("topk", resp)?;
    let top = resp
        .get("top")
        .and_then(Json::as_arr)
        .ok_or("topk: no top array")?;
    let pairs: Vec<String> = top
        .iter()
        .map(|p| {
            let pair = p.as_arr().unwrap_or(&[]);
            let field = |i: usize| {
                pair.get(i).and_then(Json::as_f64).unwrap_or(-1.0)
            };
            format!("{}:{}", field(0), field(1))
        })
        .collect();
    println!(
        "TOPK OK tenant={tenant} col={col} k={k} top=[{}]",
        pairs.join(",")
    );
    Ok(())
}

fn ping(addr: &str) -> Result<(), String> {
    let mut c = connect(addr)?;
    match c.ping() {
        Ok(true) => {
            println!("PONG {addr}");
            Ok(())
        }
        Ok(false) => Err(format!("ping {addr}: server answered an error")),
        Err(e) => Err(format!("ping {addr}: {e}")),
    }
}

fn smoke(addr: &str, tenant: &str) -> Result<(), String> {
    let mut c = connect(addr)?;
    let schema = Json::obj([(
        "columns",
        Json::Arr(vec![Json::obj([
            ("name", "k".into()),
            ("values", KEYS.to_vec().into()),
        ])]),
    )]);
    // Small flush cadence so the smoke pass crosses the memtable ->
    // segment boundary (and the restart in `ci.sh --serve` replays a
    // WAL tail, not just reopens segments).
    let cfg = Json::obj([("flush_batches", 2.into())]);
    let resp = c
        .create_tenant(tenant, &schema, Some(&cfg))
        .map_err(|e| format!("create_tenant: {e}"))?;
    expect_ok("create_tenant", resp)?;
    for i in 0..SMOKE_BATCHES {
        let resp = c
            .ingest(tenant, &smoke_batch(i), true)
            .map_err(|e| format!("ingest batch {i}: {e}"))?;
        let resp = expect_ok("ingest", resp)?;
        if resp.get("durable").and_then(Json::as_bool) != Some(true) {
            return Err(format!("ingest batch {i}: receipt not durable"));
        }
    }
    check_counts(&mut c, tenant)?;
    let resp = c.scrub(tenant).map_err(|e| format!("scrub: {e}"))?;
    let resp = expect_ok("scrub", resp)?;
    if resp.get("quarantined").and_then(Json::as_arr).map(<[Json]>::len)
        != Some(0)
    {
        return Err("scrub: quarantined segments on a fresh store".into());
    }
    let stats = c.stats(tenant).map_err(|e| format!("stats: {e}"))?;
    let stats = expect_ok("stats", stats)?;
    let ingested = stats
        .get("engine")
        .and_then(|e| e.get("batches_ingested"))
        .and_then(Json::as_f64);
    if ingested != Some(SMOKE_BATCHES as f64) {
        return Err(format!(
            "stats: batches_ingested = {ingested:?}, want {SMOKE_BATCHES}"
        ));
    }
    println!(
        "SMOKE OK tenant={tenant} batches={SMOKE_BATCHES} \
         per_key={}",
        expected_per_key()
    );
    Ok(())
}

fn verify(addr: &str, tenant: &str) -> Result<(), String> {
    let mut c = connect(addr)?;
    check_counts(&mut c, tenant)?;
    let metrics = c.metrics().map_err(|e| format!("metrics: {e}"))?;
    let metrics = expect_ok("metrics", metrics)?;
    let per_tenant = metrics
        .get("tenants")
        .and_then(|t| t.get(tenant))
        .ok_or_else(|| format!("metrics: tenant {tenant} missing"))?;
    if per_tenant
        .get("engine")
        .and_then(|e| e.get("batches_ingested"))
        .and_then(Json::as_f64)
        .is_none()
    {
        return Err("metrics: engine.batches_ingested missing".into());
    }
    println!("VERIFY OK tenant={tenant} per_key={}", expected_per_key());
    Ok(())
}

/// Query every key and check the exact deterministic count.
fn check_counts(c: &mut Client, tenant: &str) -> Result<(), String> {
    for key in KEYS {
        let resp = c
            .query(tenant, &eq_predicate(key))
            .map_err(|e| format!("query k=={key}: {e}"))?;
        let resp = expect_ok("query", resp)?;
        let got = count_of(&resp);
        if got != Some(expected_per_key()) {
            return Err(format!(
                "query k=={key}: count {got:?}, want {}",
                expected_per_key()
            ));
        }
    }
    Ok(())
}

fn hammer(
    addr: &str,
    tenant: &str,
    workers: usize,
    iters: usize,
    telemetry: bool,
) -> Result<(), String> {
    let mut c = connect(addr)?;
    let schema = Json::obj([(
        "columns",
        Json::Arr(vec![Json::obj([
            ("name", "k".into()),
            ("values", KEYS.to_vec().into()),
        ])]),
    )]);
    // Racing `hammer` after `smoke` is fine: an existing tenant is a
    // config error here, not a failure.
    let cfg = telemetry.then(|| Json::obj([("telemetry", true.into())]));
    if let Ok(resp) = c.create_tenant(tenant, &schema, cfg.as_ref()) {
        if !response_ok(&resp)
            && response_error_code(&resp) != Some("config")
        {
            expect_ok("create_tenant", resp)?;
        }
    }
    let start = std::time::Instant::now();
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let addr = addr.to_string();
                let tenant = tenant.to_string();
                s.spawn(move || hammer_worker(&addr, &tenant, w, iters))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    });
    let elapsed = start.elapsed().as_secs_f64().max(1e-9);
    let mut total_ops = 0u64;
    let mut total_busy = 0u64;
    let mut total_lat = HistSnapshot::default();
    for (w, r) in results.into_iter().enumerate() {
        let (ops, busy, lat) = r
            .map_err(|_| format!("worker {w} panicked"))?
            .map_err(|e| format!("worker {w}: {e}"))?;
        println!(
            "worker {w}: {ops} ops, {busy} busy retries, {:.0} ops/sec, \
             lat p50={} p99={} max={} us",
            ops as f64 / elapsed,
            lat.quantile(0.5) / 1_000,
            lat.quantile(0.99) / 1_000,
            lat.max / 1_000,
        );
        total_ops += ops;
        total_busy += busy;
        total_lat.merge(&lat);
    }
    println!(
        "HAMMER OK workers={workers} total_ops={total_ops} \
         busy_retries={total_busy} total_ops_per_sec={:.0} \
         lat_p50_us={} lat_p99_us={} lat_max_us={}",
        total_ops as f64 / elapsed,
        total_lat.quantile(0.5) / 1_000,
        total_lat.quantile(0.99) / 1_000,
        total_lat.max / 1_000,
    );
    Ok(())
}

/// One hammer worker: `iters` rounds of (sync ingest + query) on its
/// own connection; `busy` answers back off and retry. Per-op wall
/// latency (busy retries included — queueing is part of the latency a
/// client observes) lands in a histogram whose snapshot merges into the
/// run total.
fn hammer_worker(
    addr: &str,
    tenant: &str,
    w: usize,
    iters: usize,
) -> Result<(u64, u64, HistSnapshot), String> {
    let mut c = connect(addr)?;
    let mut ops = 0u64;
    let mut busy = 0u64;
    let lat = Histogram::new();
    for i in 0..iters {
        let batch = smoke_batch(w * iters + i);
        let t0 = std::time::Instant::now();
        loop {
            let resp = c
                .ingest(tenant, &batch, true)
                .map_err(|e| format!("ingest: {e}"))?;
            if response_ok(&resp) {
                break;
            }
            if response_error_code(&resp) == Some("busy") {
                busy += 1;
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            expect_ok("ingest", resp)?;
        }
        lat.record(clock::to_cycles(t0.elapsed()));
        ops += 1;
        let t0 = std::time::Instant::now();
        let resp = c
            .query(tenant, &eq_predicate(KEYS[i % KEYS.len()]))
            .map_err(|e| format!("query: {e}"))?;
        expect_ok("query", resp)?;
        lat.record(clock::to_cycles(t0.elapsed()));
        ops += 1;
    }
    Ok((ops, busy, lat.snapshot()))
}

/// Assert the observability surface end to end against a tenant that
/// was hammered with `--telemetry`: `metrics` carries nonzero
/// per-tenant quantiles plus the Prometheus text, `explain` round-trips
/// (with `analyze` attaching measured counters), and `slowlog`/`trace`
/// answer without `telemetry-off`.
fn obscheck(addr: &str, tenant: &str) -> Result<(), String> {
    let mut c = connect(addr)?;

    // Drive the bit-sliced kernels once so their telemetry channels
    // have something to show (hammer only ingests and queries).
    let filter = Json::obj([
        ("col", "k".into()),
        ("between", Json::Arr(vec![KEYS[1].into(), KEYS[6].into()])),
    ]);
    let resp = c
        .aggregate(tenant, "k", "sum", Some(&filter))
        .map_err(|e| format!("aggregate: {e}"))?;
    let resp = expect_ok("aggregate", resp)?;
    if resp.get("rows").and_then(Json::as_f64).is_none() {
        return Err("aggregate: no rows field".into());
    }
    let resp = c
        .topk(tenant, "k", 3, None)
        .map_err(|e| format!("topk: {e}"))?;
    let resp = expect_ok("topk", resp)?;
    if resp.get("top").and_then(Json::as_arr).is_none() {
        return Err("topk: no top array".into());
    }

    // metrics: per-tenant telemetry quantiles present and nonzero.
    let metrics = c.metrics().map_err(|e| format!("metrics: {e}"))?;
    let metrics = expect_ok("metrics", metrics)?;
    let telem = metrics
        .get("tenants")
        .and_then(|t| t.get(tenant))
        .and_then(|t| t.get("telemetry"))
        .ok_or_else(|| {
            format!("metrics: tenants.{tenant}.telemetry missing")
        })?;
    for channel in ["ingest_ack", "query"] {
        let h = telem.get(channel).ok_or_else(|| {
            format!("metrics: telemetry.{channel} missing")
        })?;
        // `query` is keyed by tier; take the busiest one.
        let h = if channel == "query" {
            match h {
                Json::Obj(map) => map
                    .values()
                    .max_by_key(|t| {
                        t.get("count")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0) as u64
                    })
                    .ok_or_else(|| "metrics: query has no tiers".to_string())?,
                _ => return Err("metrics: telemetry.query not an object".into()),
            }
        } else {
            h
        };
        let get = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        if get("count") <= 0.0 || get("p50") <= 0.0 || get("p99") <= 0.0 {
            return Err(format!(
                "metrics: telemetry.{channel} quantiles not populated \
                 (count={} p50={} p99={}); hammer with --telemetry first",
                get("count"),
                get("p50"),
                get("p99")
            ));
        }
    }
    // The aggregate/topk channels populated from the calls above.
    for channel in ["aggregate", "topk"] {
        let count = telem
            .get(channel)
            .and_then(|h| h.get("count"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if count <= 0.0 {
            return Err(format!(
                "metrics: telemetry.{channel} not populated after an \
                 obscheck-driven call (count={count})"
            ));
        }
    }
    let prom = metrics
        .get("prometheus")
        .and_then(Json::as_str)
        .ok_or("metrics: prometheus text missing")?;
    if !prom.contains("# bic_metrics_version") {
        return Err("metrics: prometheus text lacks version header".into());
    }
    if !prom.contains("bic_ingest_ack_cycles") {
        return Err("metrics: prometheus text lacks histogram series".into());
    }
    if !prom.contains("bic_aggregate_cycles")
        || !prom.contains("bic_topk_cycles")
    {
        return Err(
            "metrics: prometheus text lacks aggregate/topk series".into()
        );
    }

    // explain: round-trips and reports a tier; analyze attaches actuals.
    let resp = c
        .explain(tenant, &eq_predicate(KEYS[0]), true)
        .map_err(|e| format!("explain: {e}"))?;
    let resp = expect_ok("explain", resp)?;
    let explain = resp.get("explain").ok_or("explain: no report")?;
    if explain.get("tier").and_then(Json::as_str).is_none() {
        return Err("explain: no tier in report".into());
    }
    if explain.get("actual").is_none() {
        return Err("explain: analyze=true but no actual section".into());
    }

    // slowlog + trace: answer (telemetry on), slowlog nonempty after a
    // hammer run.
    let resp = c.slowlog(tenant).map_err(|e| format!("slowlog: {e}"))?;
    let resp = expect_ok("slowlog", resp)?;
    let entries = resp
        .get("slowlog")
        .and_then(Json::as_arr)
        .ok_or("slowlog: no entries array")?;
    if entries.is_empty() {
        return Err("slowlog: empty after a hammer run".into());
    }
    let resp = c.trace(tenant).map_err(|e| format!("trace: {e}"))?;
    let resp = expect_ok("trace", resp)?;
    if resp.get("events").and_then(Json::as_arr).is_none() {
        return Err("trace: no events array".into());
    }
    println!("OBSCHECK OK tenant={tenant}");
    Ok(())
}
