//! Query evaluation over the durable store: memtable + segments, without
//! ever materializing a fully decompressed index.
//!
//! Rows the query only references inside a top-level conjunction are
//! never assembled at all: the AND/ANDNOT offset kernels
//! (`CodecBitmap::and_into_at` / `and_not_into_at`) fold each segment's
//! compressed row into the accumulator at the segment's object offset —
//! a WAH fill lands as one word-span write, roaring dense chunks move
//! word-shifted. Rows that must be assembled (`Or` terms, single leaves)
//! OR-merge per segment through the streaming `or_into_at` kernels. The
//! assemble-then-AND path is retained as
//! [`StoreReader::eval_assembled`], the differential reference the
//! property tests pin [`StoreReader::eval`] against bit-for-bit.

use std::collections::HashMap;

use super::Store;
use crate::bic::bitmap::{Bitmap, BitmapIndex};
use crate::bic::query::{Query, QueryError};
use crate::engine::exec::{self, RowChunk};

/// A read view over a [`Store`] (memtable + live segments at the time
/// of the borrow).
pub struct StoreReader<'a> {
    store: &'a Store,
}

impl<'a> StoreReader<'a> {
    pub(crate) fn new(store: &'a Store) -> Self {
        Self { store }
    }

    /// Attribute rows per object (the store's schema width).
    #[inline]
    pub fn num_attrs(&self) -> usize {
        self.store.num_attrs
    }

    /// Total objects across segments + memtable.
    #[inline]
    pub fn num_objects(&self) -> usize {
        self.store.num_objects()
    }

    /// The chunk tiling of the global object space (the store's single
    /// tiling rule — see [`Store`]'s `chunks`).
    fn chunks(&self) -> Vec<RowChunk<'_>> {
        self.store.chunks()
    }

    /// Assemble attribute `attr`'s global row: every segment's row OR'd
    /// in at its base, then the memtable batches at theirs.
    pub fn assemble_row(&self, attr: usize) -> Result<Bitmap, QueryError> {
        if attr >= self.num_attrs() {
            return Err(QueryError::AttrOutOfRange(attr, self.num_attrs()));
        }
        Ok(exec::assemble_row(&self.chunks(), attr, self.num_objects()))
    }

    /// Evaluate a query spanning memtable + segments. Result-identical
    /// to [`StoreReader::eval_assembled`] (the property tests pin this),
    /// but conjunction terms fold segment-by-segment through the offset
    /// AND/ANDNOT kernels and only `Or`/leaf rows are assembled.
    pub fn eval(&self, q: &Query) -> Result<Bitmap, QueryError> {
        let m = self.num_attrs();
        for a in q.attrs() {
            if a >= m {
                return Err(QueryError::AttrOutOfRange(a, m));
            }
        }
        Ok(exec::eval_chunks(&self.chunks(), self.num_objects(), q))
    }

    /// The assemble-then-AND reference path: every referenced row is
    /// assembled to full length first, then the query evaluates over the
    /// assembled rows. Retained as the differential baseline for
    /// [`StoreReader::eval`]; queries should use `eval`.
    pub fn eval_assembled(&self, q: &Query) -> Result<Bitmap, QueryError> {
        let m = self.num_attrs();
        let attrs = q.attrs(); // sorted, deduplicated
        for &a in &attrs {
            if a >= m {
                return Err(QueryError::AttrOutOfRange(a, m));
            }
        }
        if attrs.is_empty() {
            // No rows referenced: evaluation only needs the object
            // count (And([]) = all, Or([]) = none, and compositions).
            let bi =
                BitmapIndex::from_rows(vec![Bitmap::zeros(self.num_objects())]);
            return q.eval(&bi);
        }
        let map: HashMap<usize, usize> =
            attrs.iter().enumerate().map(|(dense, &a)| (a, dense)).collect();
        let chunks = self.chunks();
        let rows: Vec<Bitmap> = attrs
            .iter()
            .map(|&a| exec::assemble_row(&chunks, a, self.num_objects()))
            .collect();
        let bi = BitmapIndex::from_rows(rows);
        let dense_q = q.remap(&map);
        dense_q.eval(&bi)
    }

    /// Materialize the whole index (every attribute assembled) — the
    /// differential reference for tests; queries should go through
    /// [`StoreReader::eval`].
    pub fn to_index(&self) -> BitmapIndex {
        let chunks = self.chunks();
        let rows = (0..self.num_attrs())
            .map(|a| exec::assemble_row(&chunks, a, self.num_objects()))
            .collect();
        BitmapIndex::from_rows(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remap_rewrites_every_leaf() {
        let q = Query::attr(7)
            .and(Query::attr(3))
            .or(Query::attr(7).not())
            .and(Query::And(vec![]));
        let map: HashMap<usize, usize> = [(3, 0), (7, 1)].into_iter().collect();
        let r = q.remap(&map);
        assert_eq!(r.attrs(), vec![0, 1]);
        assert_eq!(q.op_count(), r.op_count());
    }
}
