//! Query evaluation over the durable store: memtable + segments, without
//! ever materializing a fully decompressed index.
//!
//! Each attribute row the query references is assembled once into a
//! global-length accumulator by OR-merging the per-segment rows at their
//! object offsets — run by run, through the streaming `or_into_at`
//! kernels (a WAH fill lands as one word-span write, roaring dense
//! chunks move word-shifted). Rows the query never touches are never
//! assembled; nothing else is decompressed.

use std::collections::HashMap;

use super::Store;
use crate::bic::bitmap::{Bitmap, BitmapIndex};
use crate::bic::query::{Query, QueryError};

/// A read view over a [`Store`] (memtable + live segments at the time
/// of the borrow).
pub struct StoreReader<'a> {
    store: &'a Store,
}

impl<'a> StoreReader<'a> {
    pub(crate) fn new(store: &'a Store) -> Self {
        Self { store }
    }

    #[inline]
    pub fn num_attrs(&self) -> usize {
        self.store.num_attrs
    }

    #[inline]
    pub fn num_objects(&self) -> usize {
        self.store.num_objects()
    }

    /// Assemble attribute `attr`'s global row: every segment's row OR'd
    /// in at its base, then the memtable batches at theirs.
    pub fn assemble_row(&self, attr: usize) -> Bitmap {
        assert!(attr < self.num_attrs(), "attr {attr} out of range");
        let mut acc = Bitmap::zeros(self.num_objects());
        for seg in &self.store.segments {
            seg.rows[attr].or_into_at(&mut acc, seg.base);
        }
        let mut off = self.store.segment_bits();
        for batch in &self.store.memtable {
            batch[attr].or_into_at(&mut acc, off);
            off += batch[attr].len();
        }
        acc
    }

    /// Evaluate a query spanning memtable + segments. Result-identical
    /// to `Query::eval` over [`StoreReader::to_index`] (the property
    /// tests pin this), but only the referenced rows are assembled.
    pub fn eval(&self, q: &Query) -> Result<Bitmap, QueryError> {
        let m = self.num_attrs();
        let attrs = q.attrs(); // sorted, deduplicated
        for &a in &attrs {
            if a >= m {
                return Err(QueryError::AttrOutOfRange(a, m));
            }
        }
        if attrs.is_empty() {
            // No rows referenced: evaluation only needs the object
            // count (And([]) = all, Or([]) = none, and compositions).
            let bi =
                BitmapIndex::from_rows(vec![Bitmap::zeros(self.num_objects())]);
            return Ok(q.eval(&bi).expect("no attrs referenced"));
        }
        let map: HashMap<usize, usize> =
            attrs.iter().enumerate().map(|(dense, &a)| (a, dense)).collect();
        let rows: Vec<Bitmap> =
            attrs.iter().map(|&a| self.assemble_row(a)).collect();
        let bi = BitmapIndex::from_rows(rows);
        let dense_q = remap(q, &map);
        Ok(dense_q.eval(&bi).expect("remapped attrs are dense and in range"))
    }

    /// Materialize the whole index (every attribute assembled) — the
    /// differential reference for tests; queries should go through
    /// [`StoreReader::eval`].
    pub fn to_index(&self) -> BitmapIndex {
        let rows =
            (0..self.num_attrs()).map(|a| self.assemble_row(a)).collect();
        BitmapIndex::from_rows(rows)
    }
}

/// Rewrite a query's attribute ids through `map` (total on the query's
/// attrs by construction).
fn remap(q: &Query, map: &HashMap<usize, usize>) -> Query {
    match q {
        Query::Attr(a) => Query::Attr(map[a]),
        Query::And(xs) => Query::And(xs.iter().map(|x| remap(x, map)).collect()),
        Query::Or(xs) => Query::Or(xs.iter().map(|x| remap(x, map)).collect()),
        Query::Not(inner) => Query::Not(Box::new(remap(inner, map))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remap_rewrites_every_leaf() {
        let q = Query::attr(7)
            .and(Query::attr(3))
            .or(Query::attr(7).not())
            .and(Query::And(vec![]));
        let map: HashMap<usize, usize> = [(3, 0), (7, 1)].into_iter().collect();
        let r = remap(&q, &map);
        assert_eq!(r.attrs(), vec![0, 1]);
        assert_eq!(q.op_count(), r.op_count());
    }
}
