//! Durable segment store: the crash-safe persistent home of the bitmap
//! index — the missing piece between the in-memory
//! [`CompressedIndex`](crate::bic::codec::CompressedIndex) tier and the
//! paper's peak/off-peak story (index hard during peak hours, then hold
//! state at near-zero power: state you lose on power-off is not held).
//!
//! Architecture (LSM-lite, append-only):
//!
//! - **WAL** ([`wal`]) — every acknowledged batch is first appended to a
//!   checksummed write-ahead log and fsynced; the in-memory memtable is
//!   always reconstructible from it.
//! - **Segments** ([`segment`]) — the memtable flushes into immutable
//!   segment files: checksummed header, per-attribute row directory with
//!   offsets, then codec-tagged row payloads (the same adaptive
//!   raw/WAH/roaring encodings the query tier executes on).
//! - **Manifest** ([`manifest`]) — the single source of truth for the
//!   live segment set, replaced atomically (temp file + rename), so a
//!   crash at any byte leaves either the old or the new store view,
//!   never a torn one. Each flush rotates the WAL generation through the
//!   same commit, so replay can never double-count a flushed batch.
//! - **Reader** ([`reader`]) — answers [`Query`](crate::bic::Query)
//!   evaluations spanning memtable + segments by OR-merging each
//!   referenced attribute row across segments run-by-run (the streaming
//!   `or_into_at` kernels), never materializing a fully decompressed
//!   index.
//! - **Compaction** ([`compaction`]) — a background
//!   [`Compactor`](compaction::Compactor) merges small segments into
//!   larger ones, tombstoning superseded files through the manifest.
//! - **VFS** ([`vfs`]) — every byte of store I/O flows through the
//!   [`Vfs`] seam: [`RealVfs`] in production,
//!   [`FaultVfs`](vfs::FaultVfs) injecting seeded crashes / torn writes
//!   / fsync failures / bit-flips in the chaos tests.
//! - **Scrubber** ([`scrub`]) — re-verifies segment checksums and zone
//!   invariants from disk on demand or on a schedule, quarantining
//!   corrupt files (manifest tombstone + move to `quarantined/`)
//!   instead of letting them fail queries later.
//!
//! A quarantined segment leaves a *hole* in the object space: healthy
//! chunks keep their bases (the evaluators already tolerate
//! non-contiguous tilings — missing ranges read as zeros), and
//! [`DegradedPolicy`] decides whether reads over a holed store fail
//! closed or serve the healthy subset with the gap surfaced through
//! counters.
//!
//! Crash safety contract (property-tested in `rust/tests/store_props.rs`
//! against truncation at every byte offset *and* a seeded fault matrix
//! over every VFS call): after [`Store::recover`], the store is
//! queryable and every row is bit-identical to the in-memory reference
//! built from the prefix of batches whose [`Store::append_batch`]
//! durably returned.

pub mod compaction;
pub mod manifest;
pub mod reader;
pub mod scrub;
pub mod segment;
pub mod vfs;
pub mod wal;
pub mod zone;

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::bic::bitmap::Bitmap;
use crate::bic::clock;
use crate::bic::codec::{CodecBitmap, CompressedIndex};
use crate::obs::{Telemetry, TraceOp, TraceStage};
use self::compaction::CompactionPolicy;
pub use self::compaction::Compactor;
use self::manifest::{ManifestState, SegmentEntry};
pub use self::reader::StoreReader;
pub use self::scrub::{ScrubReport, Scrubber};
use self::segment::Segment;
pub use self::vfs::{RealVfs, Vfs, VfsFile};
pub use self::wal::AppendTicket;
use self::wal::Wal;
pub use self::zone::ZoneMap;

/// Store-layer errors. I/O failures pass through; corruption found while
/// reading (bad magic, checksum mismatch, structural violations) is
/// reported with what was being read.
#[derive(Debug, thiserror::Error)]
pub enum StoreError {
    #[error("store io: {0}")]
    Io(#[from] std::io::Error),
    #[error("corrupt {what}: {detail}")]
    Corrupt { what: &'static str, detail: String },
    #[error("store: {0}")]
    Invalid(String),
    /// A lock guarding shared store state was poisoned by a panic on
    /// another thread — the state may be torn, so the operation refuses
    /// instead of propagating the panic.
    #[error("poisoned lock: {0}")]
    Poisoned(&'static str),
}

pub type Result<T> = std::result::Result<T, StoreError>;

/// What reads do when part of the store is quarantined (corrupt or
/// missing segments tombstoned by the scrubber or degraded recovery).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DegradedPolicy {
    /// Refuse: opening a store with a corrupt committed segment errors,
    /// and queries over a store that degraded while open return a typed
    /// `Corrupt` naming a quarantined segment. Nothing is served unless
    /// everything is servable.
    #[default]
    FailClosed,
    /// Serve the healthy subset: corrupt segments quarantine (manifest
    /// tombstone + `quarantined/` move), their object ranges read as
    /// all-zero holes, and the gap is surfaced via
    /// [`Store::degraded_segments`] / [`Store::rows_unavailable`] (and
    /// the engine's stats counters).
    ServeHealthy,
}

/// Tuning knobs for a store instance.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Flush the memtable into a segment once it holds this many
    /// acknowledged batches (0 = manual flushes only).
    pub flush_batches: usize,
    /// When the background/foreground compactor merges segments.
    pub compaction: CompactionPolicy,
    /// Group-commit batching window: how long an append may wait for
    /// co-travellers before leading a WAL sync itself (bounds the added
    /// ack latency; zero syncs immediately). See [`wal`].
    pub group_window: Duration,
    /// Use segment zone maps to skip segments at query time. Writing
    /// the maps is unconditional; this gates only the read side (the
    /// differential off-switch for skip-vs-noskip testing).
    pub zone_pruning: bool,
    /// Behavior of reads over a partially-quarantined store.
    pub degraded: DegradedPolicy,
    /// The I/O layer every store read/write goes through. [`RealVfs`]
    /// (the default) is the plain filesystem; tests select
    /// [`vfs::FaultVfs`] to inject seeded faults.
    pub vfs: Arc<dyn Vfs>,
    /// Telemetry channels shared with the owning engine: when set, the
    /// store records flush durations and the WAL records group-commit
    /// write+fsync timings into it. `None` (the default) keeps the
    /// store paths free of clock reads and atomics.
    pub telemetry: Option<Arc<Telemetry>>,
    /// When set, flushed (and compacted) segments carry a bit-sliced
    /// index section over these columns (`BICSEG3`; see
    /// [`crate::bsi`]). `None` writes plain v2 segments.
    pub bsi_layout: Option<Arc<crate::bsi::BsiLayout>>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            flush_batches: 64,
            compaction: CompactionPolicy::default(),
            group_window: Duration::ZERO,
            zone_pruning: true,
            degraded: DegradedPolicy::default(),
            vfs: Arc::new(RealVfs),
            telemetry: None,
            bsi_layout: None,
        }
    }
}

/// A durable, crash-safe persistent bitmap index over one directory.
pub struct Store {
    pub(crate) dir: PathBuf,
    pub(crate) cfg: StoreConfig,
    pub(crate) num_attrs: usize,
    /// Live segments, ordered by `base`; bases are contiguous. `Arc` so
    /// an [`crate::engine::Snapshot`] can pin the segment set it was
    /// taken over while flushes/compactions replace the live list.
    pub(crate) segments: Vec<Arc<Segment>>,
    /// Tombstoned entries: segments found corrupt/missing and moved to
    /// `quarantined/`. Their object ranges stay reserved (holes in the
    /// tiling) so healthy bases never shift.
    pub(crate) quarantined: Vec<SegmentEntry>,
    pub(crate) next_segment_id: u64,
    pub(crate) wal_gen: u64,
    wal: Wal,
    /// Acknowledged batches not yet flushed (each: one row per attr).
    pub(crate) memtable: Vec<Vec<CodecBitmap>>,
    pub(crate) memtable_bits: usize,
    segment_bytes_written: u64,
    /// Maintenance counters, always collected (plain `u64` bumps on
    /// already-rare operations — no telemetry gate): scrub passes run
    /// and bytes verified, compaction rounds and segment bytes they
    /// wrote. Surfaced through [`Store::maintenance_counters`] into
    /// the engine's stats.
    pub(crate) scrub_passes: u64,
    pub(crate) scrub_bytes_verified: u64,
    pub(crate) compaction_rounds: u64,
    pub(crate) compaction_bytes_written: u64,
}

/// Subdirectory quarantined segment files are moved into (kept, not
/// deleted — an operator may still salvage rows from them).
pub(crate) const QUARANTINE_DIR: &str = "quarantined";

/// Move `file` into `dir/quarantined/`, best-effort: the entry is
/// tombstoned in the manifest regardless, so a failed move only leaves
/// a dead file behind (swept as an orphan is *not* safe here — the name
/// is still referenced — so it simply stays until the move succeeds on
/// a later scrub).
fn move_to_quarantine(vfs: &dyn Vfs, dir: &Path, file: &str) {
    let qdir = dir.join(QUARANTINE_DIR);
    if vfs.create_dir_all(&qdir).is_ok() {
        let _ = vfs.rename(&dir.join(file), &qdir.join(file));
    }
}

impl Store {
    /// Create a fresh store in `dir` (created if missing; must not
    /// already hold a store).
    pub fn create(
        dir: impl AsRef<Path>,
        num_attrs: usize,
        cfg: StoreConfig,
    ) -> Result<Store> {
        let dir = dir.as_ref().to_path_buf();
        if num_attrs == 0 {
            return Err(StoreError::Invalid("need at least one attribute".into()));
        }
        cfg.vfs.create_dir_all(&dir)?;
        if manifest::exists(&dir) {
            return Err(StoreError::Invalid(format!(
                "{} already holds a store (use open)",
                dir.display()
            )));
        }
        let state = ManifestState {
            num_attrs,
            next_segment_id: 0,
            wal_gen: 0,
            segments: Vec::new(),
        };
        manifest::commit(cfg.vfs.as_ref(), &dir, &state)?;
        let wal = Wal::create(
            cfg.vfs.as_ref(),
            &dir,
            0,
            cfg.group_window,
            cfg.telemetry.clone(),
        )?;
        Ok(Store {
            dir,
            cfg,
            num_attrs,
            segments: Vec::new(),
            quarantined: Vec::new(),
            next_segment_id: 0,
            wal_gen: 0,
            wal,
            memtable: Vec::new(),
            memtable_bits: 0,
            segment_bytes_written: 0,
            scrub_passes: 0,
            scrub_bytes_verified: 0,
            compaction_rounds: 0,
            compaction_bytes_written: 0,
        })
    }

    /// Open an existing store — always the recovery path, so a store
    /// that last closed mid-crash opens exactly like a clean one.
    pub fn open(dir: impl AsRef<Path>, cfg: StoreConfig) -> Result<Store> {
        Self::recover(dir, cfg)
    }

    /// Recover the store in `dir`: load the manifest's live segment set
    /// (verifying checksums), delete orphans (torn segment writes that
    /// never reached a manifest commit, stale WAL generations), and
    /// replay the current-generation WAL into the memtable, truncating
    /// it to the last whole, checksum-valid record.
    ///
    /// Every damaged-state shape recovery can meet is a *typed*
    /// outcome, never a panic:
    ///
    /// - no manifest → `Invalid` ("no store here");
    /// - manifest entry whose file is missing or fails its CRC →
    ///   `Corrupt` naming the path under
    ///   [`DegradedPolicy::FailClosed`], or a quarantine tombstone
    ///   (manifest re-committed, file moved to `quarantined/`) under
    ///   [`DegradedPolicy::ServeHealthy`];
    /// - duplicate segment ids or non-contiguous bases in the manifest
    ///   → `Corrupt` naming the manifest;
    /// - a crash mid-rename (temp files, uncommitted segments, stale
    ///   WAL generations) → swept as orphans, by construction never
    ///   referenced by the committed manifest.
    pub fn recover(dir: impl AsRef<Path>, cfg: StoreConfig) -> Result<Store> {
        let dir = dir.as_ref().to_path_buf();
        let vfs = Arc::clone(&cfg.vfs);
        if !manifest::exists(&dir) {
            return Err(StoreError::Invalid(format!(
                "{} holds no store (no {})",
                dir.display(),
                manifest::MANIFEST
            )));
        }
        let state = manifest::load(vfs.as_ref(), &dir)?;

        // Manifest-level invariants first: a malformed committed state
        // is manifest corruption, reported as such before any segment
        // I/O happens.
        let mut ids: Vec<u64> = state.segments.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        if ids.windows(2).any(|w| w[0] == w[1]) {
            return Err(StoreError::Corrupt {
                what: "manifest",
                detail: format!(
                    "{}: duplicate segment id in committed state",
                    dir.join(manifest::MANIFEST).display()
                ),
            });
        }

        // Load the committed segment set; bases must tile contiguously
        // (quarantined tombstones keep their ranges reserved as holes).
        let mut segments = Vec::with_capacity(state.segments.len());
        let mut quarantined: Vec<SegmentEntry> = Vec::new();
        let mut newly_quarantined = false;
        let mut expected_base = 0usize;
        for e in &state.segments {
            if e.base != expected_base {
                return Err(StoreError::Corrupt {
                    what: "manifest",
                    detail: format!(
                        "segment {} at base {} expected {}",
                        e.id, e.base, expected_base
                    ),
                });
            }
            expected_base += e.nbits;
            if e.quarantined {
                quarantined.push(e.clone());
                continue;
            }
            let path = dir.join(&e.file);
            let seg = match Segment::load(vfs.as_ref(), &path) {
                Ok(seg) => seg,
                Err(err) => {
                    let err = match err {
                        StoreError::Io(io)
                            if io.kind() == std::io::ErrorKind::NotFound =>
                        {
                            StoreError::Corrupt {
                                what: "segment",
                                detail: format!(
                                    "{}: missing file referenced by the \
                                     manifest",
                                    path.display()
                                ),
                            }
                        }
                        other => other,
                    };
                    match (cfg.degraded, &err) {
                        // Damage (not e.g. a permission failure) under
                        // ServeHealthy: tombstone and keep going.
                        (
                            DegradedPolicy::ServeHealthy,
                            StoreError::Corrupt { .. },
                        ) => {
                            move_to_quarantine(vfs.as_ref(), &dir, &e.file);
                            let mut entry = e.clone();
                            entry.quarantined = true;
                            quarantined.push(entry);
                            newly_quarantined = true;
                            continue;
                        }
                        _ => return Err(err),
                    }
                }
            };
            if seg.id != e.id
                || seg.base != e.base
                || seg.nbits != e.nbits
                || seg.rows.len() != state.num_attrs
            {
                return Err(StoreError::Corrupt {
                    what: "segment",
                    detail: format!(
                        "{} disagrees with manifest entry (id {} base {} \
                         nbits {} attrs {})",
                        e.file, e.id, e.base, e.nbits, state.num_attrs
                    ),
                });
            }
            segments.push(Arc::new(seg));
        }

        // Anything quarantined during this recovery becomes part of the
        // committed truth before the store serves a single read.
        if newly_quarantined {
            let mut entries: Vec<SegmentEntry> = segments
                .iter()
                .map(|s| SegmentEntry {
                    id: s.id,
                    file: s.file.clone(),
                    base: s.base,
                    nbits: s.nbits,
                    bytes: s.bytes,
                    quarantined: false,
                })
                .chain(quarantined.iter().cloned())
                .collect();
            entries.sort_by_key(|e| e.base);
            manifest::commit(
                vfs.as_ref(),
                &dir,
                &ManifestState {
                    num_attrs: state.num_attrs,
                    next_segment_id: state.next_segment_id,
                    wal_gen: state.wal_gen,
                    segments: entries,
                },
            )?;
        }

        // Tombstone cleanup: anything with a store-owned name that the
        // manifest does not reference is a leftover of an interrupted
        // flush/compaction — a segment written but never committed, a
        // temp file mid-write, a WAL of a rotated-away generation.
        let live_wal = wal::file_name(state.wal_gen);
        for name in vfs.list(&dir)? {
            if name == manifest::MANIFEST
                || name == live_wal
                || name == QUARANTINE_DIR
            {
                continue;
            }
            let committed = state.segments.iter().any(|e| e.file == name);
            let ours = name.starts_with("seg-")
                || name.starts_with("wal-")
                || name.ends_with(".tmp");
            if ours && !committed {
                let _ = vfs.remove_file(&dir.join(&name));
            }
        }

        // Replay the WAL: the valid prefix is the durably-acknowledged
        // batch set since the last flush.
        let (memtable, valid_len) =
            wal::replay(vfs.as_ref(), &dir, state.wal_gen, state.num_attrs)?;
        let wal = Wal::open_truncated(
            vfs.as_ref(),
            &dir,
            state.wal_gen,
            valid_len,
            cfg.group_window,
            cfg.telemetry.clone(),
        )?;
        let memtable_bits = memtable
            .iter()
            .map(|rows| rows.first().map_or(0, CodecBitmap::len))
            .sum();

        Ok(Store {
            dir,
            cfg,
            num_attrs: state.num_attrs,
            segments,
            quarantined,
            next_segment_id: state.next_segment_id,
            wal_gen: state.wal_gen,
            wal,
            memtable,
            memtable_bits,
            segment_bytes_written: 0,
            scrub_passes: 0,
            scrub_bytes_verified: 0,
            compaction_rounds: 0,
            compaction_bytes_written: 0,
        })
    }

    #[inline]
    pub fn num_attrs(&self) -> usize {
        self.num_attrs
    }

    /// Total objects across segments + memtable.
    pub fn num_objects(&self) -> usize {
        self.segment_bits() + self.memtable_bits
    }

    /// Objects covered by flushed segments — including quarantined
    /// ranges, whose bases stay reserved so the next flush can never
    /// overlap a hole.
    pub(crate) fn segment_bits(&self) -> usize {
        let healthy = self.segments.last().map_or(0, |s| s.base + s.nbits);
        let holed = self
            .quarantined
            .iter()
            .map(|e| e.base + e.nbits)
            .max()
            .unwrap_or(0);
        healthy.max(holed)
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Quarantined (tombstoned) segments — the degraded-read gap.
    pub fn degraded_segments(&self) -> usize {
        self.quarantined.len()
    }

    /// Objects inside quarantined ranges: rows a query cannot see.
    /// Under [`DegradedPolicy::ServeHealthy`] those ranges read as
    /// zeros; this counter is how callers know results are partial.
    pub fn rows_unavailable(&self) -> usize {
        self.quarantined.iter().map(|e| e.nbits).sum()
    }

    /// The quarantined manifest entries (file names still referenced as
    /// tombstones; the files themselves live under `quarantined/`).
    pub fn quarantined_entries(&self) -> &[SegmentEntry] {
        &self.quarantined
    }

    /// The reads-over-holes policy this store was opened with.
    pub fn degraded_policy(&self) -> DegradedPolicy {
        self.cfg.degraded
    }

    /// Acknowledged batches still in the memtable (WAL-covered).
    pub fn memtable_batches(&self) -> usize {
        self.memtable.len()
    }

    /// Cumulative segment bytes written by this handle (flushes +
    /// compactions) — the extmem-side accounting quantity.
    pub fn segment_bytes_written(&self) -> u64 {
        self.segment_bytes_written
    }

    /// The maintenance counters in one shot: `[scrub_passes,
    /// scrub_bytes_verified, compaction_rounds,
    /// compaction_bytes_written]`. Always collected (telemetry on or
    /// off); reset when the handle is reopened, like
    /// [`Store::segment_bytes_written`].
    pub(crate) fn maintenance_counters(&self) -> [u64; 4] {
        [
            self.scrub_passes,
            self.scrub_bytes_verified,
            self.compaction_rounds,
            self.compaction_bytes_written,
        ]
    }

    /// Append one encoded batch. Returns once the batch is durable in
    /// the WAL (fsynced); may trigger an auto-flush.
    pub fn append_batch(&mut self, ci: &CompressedIndex) -> Result<()> {
        self.begin_append_batch(ci)?.wait()
    }

    /// [`Store::begin_append`] over an encoded batch.
    pub fn begin_append_batch(
        &mut self,
        ci: &CompressedIndex,
    ) -> Result<AppendTicket> {
        if ci.num_attrs() != self.num_attrs {
            return Err(StoreError::Invalid(format!(
                "batch has {} attrs, store has {}",
                ci.num_attrs(),
                self.num_attrs
            )));
        }
        self.begin_append(ci.rows().to_vec())
    }

    /// [`Store::append_batch`] over pre-encoded rows (one per attribute,
    /// all the same length).
    pub fn append_rows(&mut self, rows: Vec<CodecBitmap>) -> Result<()> {
        self.begin_append(rows)?.wait()
    }

    /// Submit one batch for append and return its durability ticket:
    /// the rows are validated, framed into the WAL's pending buffer,
    /// and applied to the memtable — all cheap — and
    /// [`AppendTicket::wait`] then blocks until the record is fsynced,
    /// riding a **group commit** when other appends are in flight.
    /// Callers holding a lock around the store (the engine, the index
    /// service) submit under the lock and wait outside it, so `k`
    /// concurrent appenders share one fsync instead of serializing `k`.
    ///
    /// May trigger an auto-flush, which drives every pending submission
    /// durable first (a returned ticket is then already acknowledged —
    /// its `wait` is free).
    ///
    /// Failure contract: the rows become memtable-visible at submit
    /// time. If the group sync later fails, the ticket's `wait` errors
    /// and the WAL generation is poisoned — every further append *and*
    /// flush on this handle errors, so the unacknowledged rows can
    /// never be persisted, but a live handle may still serve reads
    /// that include them. Reopen the store to recover exactly the
    /// acknowledged prefix.
    pub fn begin_append(
        &mut self,
        rows: Vec<CodecBitmap>,
    ) -> Result<AppendTicket> {
        if rows.len() != self.num_attrs {
            return Err(StoreError::Invalid(format!(
                "batch has {} rows, store has {} attrs",
                rows.len(),
                self.num_attrs
            )));
        }
        let nbits = rows[0].len();
        if rows.iter().any(|r| r.len() != nbits) {
            return Err(StoreError::Invalid("ragged batch rows".into()));
        }
        let ticket = self.wal.submit(&rows)?;
        self.memtable_bits += nbits;
        self.memtable.push(rows);
        if self.cfg.flush_batches > 0
            && self.memtable.len() >= self.cfg.flush_batches
        {
            self.flush()?;
        }
        Ok(ticket)
    }

    /// Flush the memtable into an immutable segment: concatenate each
    /// attribute's batch rows (streamed at their object offsets, no
    /// full-index materialization), re-encode adaptively, write the
    /// segment file (temp + fsync + rename), commit the manifest with
    /// the segment added and the WAL generation rotated, then drop the
    /// old WAL. Returns the segment bytes written, or `None` when the
    /// memtable was empty.
    pub fn flush(&mut self) -> Result<Option<u64>> {
        if self.memtable.is_empty() {
            return Ok(None);
        }
        let t0 = self.cfg.telemetry.as_ref().map(|_| Instant::now());
        // Drive every outstanding group-commit submission durable before
        // the generation rotates: a ticket must never be stranded behind
        // a WAL the manifest no longer references.
        self.wal.sync_pending()?;
        let base = self.segment_bits();
        let nbits = self.memtable_bits;
        let rows: Vec<CodecBitmap> = (0..self.num_attrs)
            .map(|a| {
                let mut acc = Bitmap::zeros(nbits);
                let mut off = 0usize;
                for batch in &self.memtable {
                    batch[a].or_into_at(&mut acc, off);
                    off += batch[a].len();
                }
                CodecBitmap::from_bitmap(&acc)
            })
            .collect();

        let id = self.next_segment_id;
        let (file, bytes, zone, bsi) = segment::write(
            self.vfs(),
            &self.dir,
            id,
            base,
            &rows,
            self.cfg.bsi_layout.as_deref(),
        )?;
        let new_gen = self.wal_gen + 1;
        // Open the next WAL generation *before* the commit: every
        // fallible step happens while the old state is still the
        // committed truth (an error here leaves the handle fully
        // consistent, and the pre-created file is just an orphan the
        // next recovery sweeps). After the commit the swap below is
        // infallible, so the handle can never keep acknowledging
        // appends into a generation the manifest has rotated away.
        let new_wal = Wal::create(
            self.vfs(),
            &self.dir,
            new_gen,
            self.cfg.group_window,
            self.cfg.telemetry.clone(),
        )?;
        let mut entries = self.manifest_entries();
        entries.push(SegmentEntry {
            id,
            file: file.clone(),
            base,
            nbits,
            bytes,
            quarantined: false,
        });
        manifest::commit(
            self.vfs(),
            &self.dir,
            &ManifestState {
                num_attrs: self.num_attrs,
                next_segment_id: id + 1,
                wal_gen: new_gen,
                segments: entries,
            },
        )?;
        // Committed: the segment is live and the old WAL generation is
        // dead (recovery ignores it even if the unlink below never runs).
        let old_wal = wal::path(&self.dir, self.wal_gen);
        self.wal = new_wal;
        let _ = self.cfg.vfs.remove_file(&old_wal);
        self.wal_gen = new_gen;
        self.next_segment_id = id + 1;
        self.segments.push(Arc::new(Segment {
            id,
            file,
            base,
            nbits,
            bytes,
            rows,
            zone: Some(zone),
            bsi,
        }));
        self.memtable.clear();
        self.memtable_bits = 0;
        self.segment_bytes_written += bytes;
        if let (Some(t), Some(t0)) = (self.cfg.telemetry.as_deref(), t0) {
            let dur = clock::to_cycles(t0.elapsed());
            t.flush.record(dur);
            t.ring.push(TraceOp::Flush, TraceStage::Run, dur, bytes);
        }
        Ok(Some(bytes))
    }

    /// Snapshot view for query evaluation.
    pub fn reader(&self) -> StoreReader<'_> {
        StoreReader::new(self)
    }

    /// The chunk tiling of the global object space: every live segment
    /// at its base (carrying its zone map when `zone_pruning` is on),
    /// then the memtable batches at theirs (always zone-unknown). This
    /// is the *single source* of the tiling rule — the reader and every
    /// engine query tier consume it, and `Engine::snapshot` pins the
    /// same layout with `Arc` clones. Change the rule here and every
    /// consumer follows.
    ///
    /// Quarantined ranges are simply absent: the evaluators OR/fold
    /// each chunk at its own base into a zeroed accumulator, so a hole
    /// reads as all-zero rows — the ServeHealthy degraded semantics.
    pub(crate) fn chunks(&self) -> Vec<crate::engine::exec::RowChunk<'_>> {
        use crate::engine::exec::RowChunk;
        let prune = self.cfg.zone_pruning;
        let mut out: Vec<RowChunk<'_>> = self
            .segments
            .iter()
            .map(|s| RowChunk {
                base: s.base,
                rows: &s.rows,
                zone: if prune { s.zone.as_ref() } else { None },
                bsi: s.bsi.as_ref(),
            })
            .collect();
        let mut off = self.segment_bits();
        for batch in &self.memtable {
            out.push(RowChunk {
                base: off,
                rows: batch,
                zone: None,
                bsi: None,
            });
            off += batch.first().map_or(0, CodecBitmap::len);
        }
        out
    }

    /// The manifest entries for the current committed set: live
    /// segments plus quarantine tombstones, ordered by base.
    pub(crate) fn manifest_entries(&self) -> Vec<SegmentEntry> {
        let mut entries: Vec<SegmentEntry> = self
            .segments
            .iter()
            .map(|s| SegmentEntry {
                id: s.id,
                file: s.file.clone(),
                base: s.base,
                nbits: s.nbits,
                bytes: s.bytes,
                quarantined: false,
            })
            .chain(self.quarantined.iter().cloned())
            .collect();
        entries.sort_by_key(|e| e.base);
        entries
    }

    /// The store's I/O layer.
    pub(crate) fn vfs(&self) -> &dyn Vfs {
        self.cfg.vfs.as_ref()
    }

    pub(crate) fn note_segment_bytes(&mut self, bytes: u64) {
        self.segment_bytes_written += bytes;
    }
}
