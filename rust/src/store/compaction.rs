//! Background compaction: merge small segments into larger ones so the
//! live set (and per-query segment fan-in) stays bounded as ingest runs.
//!
//! The picker is **size-tiered**: segments are bucketed by the power of
//! two of their on-disk size, and one round merges a whole adjacent run
//! of at least `tier_width` same-class segments — preferring the
//! *smallest* size class, so freshly flushed small segments coalesce
//! long before anything rewrites a large one (write amplification stays
//! logarithmic instead of quadratic, unlike the old adjacent-pair
//! heuristic that re-merged its own output). Adjacency is required
//! because segment bases must keep tiling the object space
//! contiguously. When the live set exceeds `max_segments` but no tier
//! has a wide-enough run, the smallest-combined adjacent pair merges as
//! a fallback, so compaction always makes progress.
//!
//! A merge is crash-atomic the same way a flush is: the merged segment
//! is fully written + fsynced first (its zone map recomputed over the
//! merged rows, so pruning survives compaction), then one manifest
//! commit swaps it in for its inputs (tombstoning them — they stop
//! being referenced), then the input files are unlinked. A crash
//! anywhere leaves either the old set or the new set live; orphaned
//! files are removed on recovery.
//!
//! Quarantined segments are never merged across: a merged segment
//! claims the whole object range `[base, base + Σnbits)`, so merging
//! over a hole would silently resurrect unavailable rows as zeros.
//! The picker therefore runs inside each maximal *object-contiguous*
//! run of healthy segments (a store with no quarantine is one run, and
//! the behavior is exactly the pre-quarantine picker's).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::manifest::{self, ManifestState, SegmentEntry};
use super::segment::{self, Segment};
use super::{Result, Store};
use crate::bic::bitmap::Bitmap;
use crate::bic::clock;
use crate::bic::codec::CodecBitmap;
use crate::obs::{TraceOp, TraceStage};

/// When and what to merge.
#[derive(Clone, Copy, Debug)]
pub struct CompactionPolicy {
    /// Merge (one tier run or fallback pair per round) while the live
    /// segment count exceeds this.
    pub max_segments: usize,
    /// Minimum adjacent same-size-class run length that merges as a
    /// tier (values below 2 behave as 2).
    pub tier_width: usize,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        Self { max_segments: 4, tier_width: 2 }
    }
}

/// Size class: the power-of-two bucket of a segment's on-disk bytes.
fn size_class(bytes: u64) -> u32 {
    64 - bytes.max(1).leading_zeros()
}

/// The range `[start, end)` one compaction round merges, or `None` when
/// the live set is within policy. Pure so the picker is unit-testable:
/// the smallest size class with an adjacent run of `>= tier_width`
/// members wins (leftmost run on ties); with no such run, the
/// smallest-combined adjacent pair keeps compaction progressing.
fn pick_range(
    sizes: &[u64],
    max_segments: usize,
    tier_width: usize,
) -> Option<(usize, usize)> {
    if sizes.len() <= max_segments.max(1) {
        return None;
    }
    let k = tier_width.max(2);
    let classes: Vec<u32> = sizes.iter().map(|&b| size_class(b)).collect();
    let mut pick: Option<(usize, usize, u32)> = None;
    let mut i = 0usize;
    while i < classes.len() {
        let mut j = i + 1;
        while j < classes.len() && classes[j] == classes[i] {
            j += 1;
        }
        let better = match pick {
            None => true,
            Some((_, _, c)) => classes[i] < c,
        };
        if j - i >= k && better {
            pick = Some((i, j, classes[i]));
        }
        i = j;
    }
    if let Some((s, e, _)) = pick {
        return Some((s, e));
    }
    // Fallback: smallest-combined adjacent pair.
    let mut best = 0usize;
    let mut best_bytes = u64::MAX;
    for (i, pair) in sizes.windows(2).enumerate() {
        let combined = pair[0] + pair[1];
        if combined < best_bytes {
            best_bytes = combined;
            best = i;
        }
    }
    Some((best, best + 2))
}

/// Maximal runs of object-contiguous segments, as `[start, end)` index
/// ranges — merge candidates never span a quarantine hole.
fn contiguous_runs(spans: &[(usize, usize)]) -> Vec<(usize, usize)> {
    let mut runs = Vec::new();
    let mut start = 0usize;
    for i in 1..spans.len() {
        let (prev_base, prev_nbits) = spans[i - 1];
        if spans[i].0 != prev_base + prev_nbits {
            runs.push((start, i));
            start = i;
        }
    }
    if !spans.is_empty() {
        runs.push((start, spans.len()));
    }
    runs
}

impl Store {
    /// One compaction round: merge the segment range the size-tiered
    /// picker chose (see module docs). Returns whether a merge happened.
    pub fn compact_once(&mut self) -> Result<bool> {
        let policy = self.cfg.compaction;
        if self.segments.len() <= policy.max_segments.max(1) {
            return Ok(false);
        }
        let spans: Vec<(usize, usize)> =
            self.segments.iter().map(|s| (s.base, s.nbits)).collect();
        let sizes: Vec<u64> = self.segments.iter().map(|s| s.bytes).collect();
        // Pick within each contiguous run (the policy's count trigger
        // already fired globally, so the per-run bound is 1: any run of
        // two or more may merge); the cheapest pick across runs wins.
        let mut best: Option<(usize, usize, u64)> = None;
        for (rs, re) in contiguous_runs(&spans) {
            let Some((s, e)) =
                pick_range(&sizes[rs..re], 1, policy.tier_width)
            else {
                continue;
            };
            let (start, end) = (rs + s, rs + e);
            let combined: u64 = sizes[start..end].iter().sum();
            if best.is_none_or(|(_, _, b)| combined < b) {
                best = Some((start, end, combined));
            }
        }
        let Some((start, end, _)) = best else {
            return Ok(false);
        };
        self.merge_range(start, end)?;
        Ok(true)
    }

    /// Merge segments `[start, end)` into one: each input row streamed
    /// at its offset within the merged range, re-encoded adaptively,
    /// with the zone map recomputed at write.
    fn merge_range(&mut self, start: usize, end: usize) -> Result<()> {
        let t0 = self.cfg.telemetry.as_ref().map(|_| Instant::now());
        let span = &self.segments[start..end];
        let base = span[0].base;
        let nbits: usize = span.iter().map(|s| s.nbits).sum();
        debug_assert!(
            span.windows(2).all(|w| w[1].base == w[0].base + w[0].nbits),
            "merge range must be object-contiguous (no quarantine holes)"
        );
        let rows: Vec<CodecBitmap> = (0..self.num_attrs)
            .map(|a| {
                let mut acc = Bitmap::zeros(nbits);
                let mut off = 0usize;
                for s in span {
                    s.rows[a].or_into_at(&mut acc, off);
                    off += s.nbits;
                }
                CodecBitmap::from_bitmap(&acc)
            })
            .collect();
        let old_files: Vec<String> =
            span.iter().map(|s| s.file.clone()).collect();

        let id = self.next_segment_id;
        let (file, bytes, zone, bsi) = segment::write(
            self.vfs(),
            &self.dir,
            id,
            base,
            &rows,
            self.cfg.bsi_layout.as_deref(),
        )?;
        // `start..end` indexes the healthy list; build the committed
        // entry set by splicing there, then re-interleaving the
        // quarantine tombstones by base.
        let mut entries: Vec<SegmentEntry> = self
            .segments
            .iter()
            .map(|s| SegmentEntry {
                id: s.id,
                file: s.file.clone(),
                base: s.base,
                nbits: s.nbits,
                bytes: s.bytes,
                quarantined: false,
            })
            .collect();
        let merged_entry = SegmentEntry {
            id,
            file: file.clone(),
            base,
            nbits,
            bytes,
            quarantined: false,
        };
        entries.splice(start..end, [merged_entry]);
        entries.extend(self.quarantined.iter().cloned());
        entries.sort_by_key(|e| e.base);
        manifest::commit(
            self.vfs(),
            &self.dir,
            &ManifestState {
                num_attrs: self.num_attrs,
                next_segment_id: id + 1,
                wal_gen: self.wal_gen,
                segments: entries,
            },
        )?;

        // Committed: the inputs are tombstoned (unreferenced); unlink
        // them now, or recovery's orphan sweep will. Pinned snapshots
        // holding the old `Arc<Segment>`s keep reading them from memory.
        let merged = Arc::new(Segment {
            id,
            file,
            base,
            nbits,
            bytes,
            rows,
            zone: Some(zone),
            bsi,
        });
        self.segments.splice(start..end, [merged]);
        self.next_segment_id = id + 1;
        self.note_segment_bytes(bytes);
        self.compaction_rounds += 1;
        self.compaction_bytes_written += bytes;
        for f in old_files {
            let _ = self.vfs().remove_file(&self.dir.join(f));
        }
        if let (Some(t), Some(t0)) = (self.cfg.telemetry.as_deref(), t0) {
            let dur = clock::to_cycles(t0.elapsed());
            t.compact.record(dur);
            t.ring.push(TraceOp::Compact, TraceStage::Run, dur, bytes);
        }
        Ok(())
    }

    /// Compact until the policy is satisfied; returns rounds run.
    pub fn compact(&mut self) -> Result<usize> {
        let mut rounds = 0usize;
        while self.compact_once()? {
            rounds += 1;
        }
        Ok(rounds)
    }
}

/// A background compaction thread over a shared store handle. Runs one
/// [`Store::compact_once`] round per tick; stops on [`Compactor::stop`]
/// or drop.
pub struct Compactor {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Compactor {
    /// Spawn the compactor, polling every `interval`.
    pub fn spawn(store: Arc<Mutex<Store>>, interval: Duration) -> Compactor {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                {
                    // A poisoned store lock means a writer panicked
                    // mid-mutation: stop compacting rather than merge
                    // over possibly-torn state.
                    let Ok(mut guard) = store.lock() else { break };
                    // I/O errors here are retried next tick; the
                    // foreground path surfaces them on its own calls.
                    let _ = guard.compact_once();
                }
                std::thread::sleep(interval);
            }
        });
        Compactor { stop, handle: Some(handle) }
    }

    /// Stop and join the background thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Compactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picker_prefers_the_smallest_tier_run() {
        // Two tiers: four same-class small segments (all within the
        // 512..1024 bucket — class boundaries matter) then two ~1MB
        // ones. The small tier merges first even though the large pair
        // is adjacent too.
        let sizes = [600, 700, 650, 620, 1 << 20, (1 << 20) + 4096];
        assert_eq!(pick_range(&sizes, 3, 2), Some((0, 4)));
        // Within policy: nothing to do.
        assert_eq!(pick_range(&sizes, 6, 2), None);
    }

    #[test]
    fn picker_falls_back_to_the_smallest_adjacent_pair() {
        // Strictly geometric sizes: no two adjacent share a class, so
        // the fallback merges the smallest-combined adjacent pair.
        let sizes = [100, 1_000, 10_000, 100_000, 1_000_000];
        assert_eq!(pick_range(&sizes, 2, 2), Some((0, 2)));
    }

    #[test]
    fn picker_honours_tier_width() {
        // A run of three equal-class segments is not enough for k = 4;
        // the fallback pair (the two smallest adjacents) fires instead.
        let sizes = [700, 720, 710, 1 << 19, 1 << 25];
        assert_eq!(pick_range(&sizes, 2, 4), Some((0, 2)));
        // With k = 2 the whole small run merges at once.
        assert_eq!(pick_range(&sizes, 2, 2), Some((0, 3)));
    }

    #[test]
    fn contiguous_runs_split_at_quarantine_holes() {
        // Three segments tiling [0,30), a hole [30,40), two more
        // tiling [40,60): two runs, never one candidate across the gap.
        let spans = [(0, 10), (10, 10), (20, 10), (40, 10), (50, 10)];
        assert_eq!(contiguous_runs(&spans), vec![(0, 3), (3, 5)]);
        // No hole: one run (the pre-quarantine behavior).
        let solid = [(0, 10), (10, 20), (30, 5)];
        assert_eq!(contiguous_runs(&solid), vec![(0, 3)]);
        assert!(contiguous_runs(&[]).is_empty());
    }

    #[test]
    fn size_classes_are_power_of_two_buckets() {
        assert_eq!(size_class(1), 1);
        assert_eq!(size_class(2), 2);
        assert_eq!(size_class(3), 2);
        assert_eq!(size_class(1024), 11);
        assert_eq!(size_class(1025), 11);
        assert_eq!(size_class(2047), 11);
        assert_eq!(size_class(2048), 12);
        assert_eq!(size_class(0), 1, "zero-byte guard");
    }
}
