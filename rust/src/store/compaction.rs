//! Background compaction: merge small segments into larger ones so the
//! live set (and per-query segment fan-in) stays bounded as ingest runs.
//!
//! A merge is crash-atomic the same way a flush is: the merged segment
//! is fully written + fsynced first, then one manifest commit swaps it
//! in for its inputs (tombstoning them — they stop being referenced),
//! then the input files are unlinked. A crash anywhere leaves either the
//! old set or the new set live; orphaned files are removed on recovery.

use std::fs;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::manifest::{self, ManifestState, SegmentEntry};
use super::segment::{self, Segment};
use super::{Result, Store};
use crate::bic::bitmap::Bitmap;
use crate::bic::codec::CodecBitmap;

/// When and what to merge.
#[derive(Clone, Copy, Debug)]
pub struct CompactionPolicy {
    /// Merge (one adjacent pair per round) while the live segment count
    /// exceeds this.
    pub max_segments: usize,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        Self { max_segments: 4 }
    }
}

impl Store {
    /// One compaction round: merge the adjacent segment pair with the
    /// smallest combined on-disk size (adjacency keeps bases
    /// contiguous). Returns whether a merge happened.
    pub fn compact_once(&mut self) -> Result<bool> {
        let max = self.cfg.compaction.max_segments.max(1);
        if self.segments.len() <= max {
            return Ok(false);
        }
        let mut pick = 0usize;
        let mut pick_bytes = u64::MAX;
        for (i, pair) in self.segments.windows(2).enumerate() {
            let combined = pair[0].bytes + pair[1].bytes;
            if combined < pick_bytes {
                pick_bytes = combined;
                pick = i;
            }
        }

        // Assemble the merged rows: each input row streamed at its
        // offset within the merged range, re-encoded adaptively.
        let (left, right) = (&self.segments[pick], &self.segments[pick + 1]);
        let nbits = left.nbits + right.nbits;
        let base = left.base;
        let rows: Vec<CodecBitmap> = (0..self.num_attrs)
            .map(|a| {
                let mut acc = Bitmap::zeros(nbits);
                left.rows[a].or_into_at(&mut acc, 0);
                right.rows[a].or_into_at(&mut acc, left.nbits);
                CodecBitmap::from_bitmap(&acc)
            })
            .collect();
        let old_files = [left.file.clone(), right.file.clone()];

        let id = self.next_segment_id;
        let (file, bytes) = segment::write(&self.dir, id, base, &rows)?;
        let mut entries: Vec<SegmentEntry> = self.manifest_entries();
        let merged_entry =
            SegmentEntry { id, file: file.clone(), base, nbits, bytes };
        entries.splice(pick..pick + 2, [merged_entry]);
        manifest::commit(
            &self.dir,
            &ManifestState {
                num_attrs: self.num_attrs,
                next_segment_id: id + 1,
                wal_gen: self.wal_gen,
                segments: entries,
            },
        )?;

        // Committed: the inputs are tombstoned (unreferenced); unlink
        // them now, or recovery's orphan sweep will. Pinned snapshots
        // holding the old `Arc<Segment>`s keep reading them from memory.
        let merged = Arc::new(Segment { id, file, base, nbits, bytes, rows });
        self.segments.splice(pick..pick + 2, [merged]);
        self.next_segment_id = id + 1;
        self.note_segment_bytes(bytes);
        for f in old_files {
            let _ = fs::remove_file(self.dir.join(f));
        }
        Ok(true)
    }

    /// Compact until the policy is satisfied; returns rounds run.
    pub fn compact(&mut self) -> Result<usize> {
        let mut rounds = 0usize;
        while self.compact_once()? {
            rounds += 1;
        }
        Ok(rounds)
    }
}

/// A background compaction thread over a shared store handle. Runs one
/// [`Store::compact_once`] round per tick; stops on [`Compactor::stop`]
/// or drop.
pub struct Compactor {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Compactor {
    /// Spawn the compactor, polling every `interval`.
    pub fn spawn(store: Arc<Mutex<Store>>, interval: Duration) -> Compactor {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                {
                    let mut guard = store.lock().expect("store lock");
                    // I/O errors here are retried next tick; the
                    // foreground path surfaces them on its own calls.
                    let _ = guard.compact_once();
                }
                std::thread::sleep(interval);
            }
        });
        Compactor { stop, handle: Some(handle) }
    }

    /// Stop and join the background thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Compactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}
