//! Per-segment zone maps: the cheapest AND is the segment you never
//! read.
//!
//! A [`ZoneMap`] records each attribute row's cardinality (set-bit
//! count) for one chunk of the object space. Segments write it into
//! their directory at flush/compaction time; the chunk-fold evaluator
//! uses it to prove a segment cannot contribute to a query term:
//!
//! - ORing or AND-NOT-ing a zero-cardinality row is a no-op — skip the
//!   segment;
//! - a conjunction whose positive leaf is zero in a segment yields a
//!   zero window for that whole segment — skip every term there (the
//!   fold's accumulator starts all-zeros, so skipping *is* the clear).
//!
//! The map is *exact* (recomputed from the rows at write, re-verified
//! against them at load), so pruning is a pure cost optimization:
//! results stay bit-identical with zone maps on or off, which the
//! engine property tests pin differentially. Chunks without a map
//! (pre-zone-map segment files, memtable batches) report "unknown" and
//! are never skipped.

use crate::bic::bitmap::Bitmap;
use crate::bic::codec::CodecBitmap;

/// Exact per-row cardinalities for one chunk, plus the derived
/// all-zero-rows bitmap (bit `a` set when row `a` has no set bits).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ZoneMap {
    cards: Vec<u64>,
    zero_rows: Bitmap,
}

impl ZoneMap {
    /// Measure `rows` (one per attribute).
    pub fn from_rows(rows: &[CodecBitmap]) -> ZoneMap {
        Self::from_cards(
            rows.iter().map(|r| r.count_ones() as u64).collect(),
        )
    }

    /// Wrap pre-measured cardinalities (the segment loader's path).
    pub(crate) fn from_cards(cards: Vec<u64>) -> ZoneMap {
        let mut zero_rows = Bitmap::zeros(cards.len());
        for (a, &c) in cards.iter().enumerate() {
            if c == 0 {
                zero_rows.set(a, true);
            }
        }
        ZoneMap { cards, zero_rows }
    }

    /// Attribute rows covered.
    #[inline]
    pub fn num_attrs(&self) -> usize {
        self.cards.len()
    }

    /// Set bits in attribute `attr`'s row of this chunk.
    #[inline]
    pub fn card(&self, attr: usize) -> u64 {
        self.cards[attr]
    }

    /// Whether attribute `attr`'s row is all zeros in this chunk.
    #[inline]
    pub fn is_zero(&self, attr: usize) -> bool {
        self.zero_rows.get(attr)
    }

    /// The raw cardinality vector (directory serialization order).
    #[inline]
    pub fn cards(&self) -> &[u64] {
        &self.cards
    }

    /// The all-zero-rows bitmap (bit `a` set iff `card(a) == 0`).
    #[inline]
    pub fn zero_rows(&self) -> &Bitmap {
        &self.zero_rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cards_and_zero_rows_agree_with_the_rows() {
        let mk = |bools: &[bool]| {
            CodecBitmap::from_bitmap(&Bitmap::from_bools(bools))
        };
        let rows = vec![
            mk(&[true, false, true, false]),
            mk(&[false, false, false, false]),
            mk(&[true, true, true, true]),
        ];
        let z = ZoneMap::from_rows(&rows);
        assert_eq!(z.num_attrs(), 3);
        assert_eq!(z.cards(), &[2, 0, 4]);
        assert!(!z.is_zero(0));
        assert!(z.is_zero(1));
        assert!(!z.is_zero(2));
        assert_eq!(z.zero_rows().count_ones(), 1);
        assert_eq!(z, ZoneMap::from_cards(vec![2, 0, 4]));
    }

    #[test]
    fn empty_map_is_degenerate_but_valid() {
        let z = ZoneMap::from_rows(&[]);
        assert_eq!(z.num_attrs(), 0);
        assert!(z.cards().is_empty());
    }
}
