//! The manifest: the atomically-replaced single source of truth for the
//! live segment set, the next segment id, and the current WAL
//! generation.
//!
//! Commit protocol: render to `MANIFEST.tmp`, fsync, `rename` over
//! `MANIFEST.json`, fsync the directory. Rename is atomic on POSIX, so a
//! crash at any byte leaves either the previous manifest or the new one
//! — never a torn file. Every mutation of the live set (flush adds a
//! segment + rotates the WAL generation; compaction swaps segments)
//! happens through exactly one commit, which is what makes those
//! operations crash-atomic.
//!
//! The format is the repo's own JSON (`substrate::json`), human-readable
//! for operability:
//!
//! ```json
//! {"version":1,"num_attrs":8,"next_segment_id":3,"wal_gen":2,
//!  "segments":[{"id":0,"file":"seg-00000000.bic","base":0,
//!               "nbits":4096,"bytes":1234}]}
//! ```

use std::path::Path;

use super::vfs::Vfs;
use super::{segment, Result, StoreError};
use crate::substrate::json::Json;

/// Manifest file name within a store directory.
pub const MANIFEST: &str = "MANIFEST.json";

const VERSION: f64 = 1.0;

/// One segment, as the manifest records it. A `quarantined` entry is a
/// tombstone: the scrubber (or degraded-mode recovery) found the file
/// corrupt or missing, moved anything salvageable to `quarantined/`,
/// and queries serve the remaining healthy set — the entry keeps its
/// object range reserved so bases never shift underneath readers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentEntry {
    pub id: u64,
    pub file: String,
    pub base: usize,
    pub nbits: usize,
    pub bytes: u64,
    pub quarantined: bool,
}

/// The full committed store state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestState {
    pub num_attrs: usize,
    pub next_segment_id: u64,
    pub wal_gen: u64,
    pub segments: Vec<SegmentEntry>,
}

/// Does `dir` hold a committed store?
pub fn exists(dir: &Path) -> bool {
    dir.join(MANIFEST).exists()
}

/// Atomically replace the manifest with `state`.
pub fn commit(vfs: &dyn Vfs, dir: &Path, state: &ManifestState) -> Result<()> {
    let doc = Json::obj([
        ("version", VERSION.into()),
        ("num_attrs", state.num_attrs.into()),
        ("next_segment_id", state.next_segment_id.into()),
        ("wal_gen", state.wal_gen.into()),
        (
            "segments",
            Json::Arr(
                state
                    .segments
                    .iter()
                    .map(|e| {
                        Json::obj([
                            ("id", e.id.into()),
                            ("file", e.file.as_str().into()),
                            ("base", e.base.into()),
                            ("nbits", e.nbits.into()),
                            ("bytes", e.bytes.into()),
                            ("quarantined", e.quarantined.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let tmp = dir.join("MANIFEST.tmp");
    {
        let mut f = vfs.create(&tmp)?;
        f.write_all(doc.render().as_bytes())?;
        f.write_all(b"\n")?;
        f.sync()?;
    }
    vfs.rename(&tmp, &dir.join(MANIFEST))?;
    segment::sync_dir(vfs, dir);
    Ok(())
}

/// A manifest-corruption error naming the offending file.
fn corrupt(path: &Path, detail: impl std::fmt::Display) -> StoreError {
    StoreError::Corrupt {
        what: "manifest",
        detail: format!("{}: {detail}", path.display()),
    }
}

/// Load and validate the manifest of `dir`.
pub fn load(vfs: &dyn Vfs, dir: &Path) -> Result<ManifestState> {
    let path = dir.join(MANIFEST);
    let bytes = vfs.read(&path)?;
    let text = String::from_utf8(bytes)
        .map_err(|_| corrupt(&path, "manifest is not UTF-8"))?;
    let doc =
        Json::parse(text.trim_end()).map_err(|e| corrupt(&path, e))?;
    let num = |key: &str| {
        doc.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| corrupt(&path, format!("missing number '{key}'")))
    };
    let version = num("version")?;
    if version != VERSION {
        return Err(corrupt(&path, format!("unknown version {version}")));
    }
    let num_attrs = num("num_attrs")? as usize;
    if num_attrs == 0 {
        return Err(corrupt(&path, "zero attributes"));
    }
    let next_segment_id = num("next_segment_id")? as u64;
    let wal_gen = num("wal_gen")? as u64;
    let arr = doc
        .get("segments")
        .and_then(Json::as_arr)
        .ok_or_else(|| corrupt(&path, "missing 'segments' array"))?;
    let mut segments = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let field = |key: &str| {
            e.get(key).and_then(Json::as_f64).ok_or_else(|| {
                corrupt(&path, format!("segment {i}: missing '{key}'"))
            })
        };
        let file = e
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| {
                corrupt(&path, format!("segment {i}: missing 'file'"))
            })?
            .to_string();
        // Manifests written before the quarantine machinery carry no
        // flag: absent means healthy.
        let quarantined = e
            .get("quarantined")
            .and_then(Json::as_bool)
            .unwrap_or(false);
        segments.push(SegmentEntry {
            id: field("id")? as u64,
            file,
            base: field("base")? as usize,
            nbits: field("nbits")? as usize,
            bytes: field("bytes")? as u64,
            quarantined,
        });
    }
    Ok(ManifestState { num_attrs, next_segment_id, wal_gen, segments })
}

#[cfg(test)]
mod tests {
    use super::super::vfs::RealVfs;
    use super::*;
    use std::fs;

    #[test]
    fn commit_load_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("bic-manifest-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        assert!(!exists(&dir));
        let state = ManifestState {
            num_attrs: 8,
            next_segment_id: 3,
            wal_gen: 2,
            segments: vec![
                SegmentEntry {
                    id: 0,
                    file: "seg-00000000.bic".into(),
                    base: 0,
                    nbits: 4096,
                    bytes: 777,
                    quarantined: false,
                },
                SegmentEntry {
                    id: 2,
                    file: "seg-00000002.bic".into(),
                    base: 4096,
                    nbits: 128,
                    bytes: 99,
                    quarantined: true,
                },
            ],
        };
        commit(&RealVfs, &dir, &state).unwrap();
        assert!(exists(&dir));
        assert_eq!(load(&RealVfs, &dir).unwrap(), state);
        // Re-commit replaces atomically (no tmp residue).
        let mut state2 = state.clone();
        state2.wal_gen = 3;
        state2.segments.pop();
        commit(&RealVfs, &dir, &state2).unwrap();
        assert_eq!(load(&RealVfs, &dir).unwrap(), state2);
        assert!(!dir.join("MANIFEST.tmp").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn pre_quarantine_manifests_load_as_healthy() {
        let dir = std::env::temp_dir()
            .join(format!("bic-manifest-compat-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        // The exact shape `commit` produced before the flag existed.
        fs::write(
            dir.join(MANIFEST),
            "{\"version\":1,\"num_attrs\":4,\"next_segment_id\":1,\
             \"wal_gen\":1,\"segments\":[{\"id\":0,\
             \"file\":\"seg-00000000.bic\",\"base\":0,\"nbits\":64,\
             \"bytes\":10}]}\n",
        )
        .unwrap();
        let state = load(&RealVfs, &dir).unwrap();
        assert_eq!(state.segments.len(), 1);
        assert!(!state.segments[0].quarantined, "absent flag = healthy");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir()
            .join(format!("bic-manifest-bad-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for bad in ["", "{}", "{\"version\":9}", "not json"] {
            fs::write(dir.join(MANIFEST), bad).unwrap();
            assert!(load(&RealVfs, &dir).is_err(), "{bad:?}");
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
