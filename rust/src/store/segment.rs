//! Immutable segment files — the durable unit of the store.
//!
//! Layout (all little-endian):
//!
//! ```text
//! [ 0.. 8)  magic  b"BICSEG1\0"
//! [ 8..16)  id     u64   segment id (manifest cross-check)
//! [16..24)  base   u64   first global object id this segment covers
//! [24..32)  nbits  u64   objects (bits per row)
//! [32..36)  m      u32   attribute row count
//! [36..36+12m)    row directory: m x { offset u64, len u32 }
//!                 (absolute file offset + byte length of each payload)
//! [.. ]     payloads: m codec-tagged rows (CodecBitmap::write_bytes)
//! [-4..]    crc32 over every preceding byte
//! ```
//!
//! Write protocol: serialize fully in memory, write to `<name>.tmp`,
//! fsync, rename into place, fsync the directory. A segment file is
//! referenced by the manifest only after this completes, so a torn
//! segment write can only ever be an orphan — recovery deletes it and
//! the WAL still covers its batches. The trailing CRC additionally
//! catches in-place corruption of committed files at load time.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use super::{Result, StoreError};
use crate::bic::codec::{read_u32, read_u64, CodecBitmap};
use crate::substrate::crc::crc32;

pub(crate) const MAGIC: &[u8; 8] = b"BICSEG1\0";
const HEADER_LEN: usize = 36;
const DIR_ENTRY_LEN: usize = 12;

/// A loaded (or just-written) segment: metadata + compressed rows in
/// memory. Rows stay in their codec encodings; the reader streams them
/// into query accumulators without decompressing the set.
pub struct Segment {
    pub(crate) id: u64,
    /// File name within the store directory.
    pub(crate) file: String,
    /// First global object id.
    pub(crate) base: usize,
    /// Objects (bits per row).
    pub(crate) nbits: usize,
    /// On-disk size in bytes.
    pub(crate) bytes: u64,
    /// One compressed row per attribute.
    pub(crate) rows: Vec<CodecBitmap>,
}

/// File name for segment `id`.
pub(crate) fn file_name(id: u64) -> String {
    format!("seg-{id:08}.bic")
}

/// Exact on-disk byte size of a segment wrapping `rows` — what the
/// scheduler's durable tier charges the extmem channel per result,
/// without serializing anything.
pub fn encoded_len(rows: &[CodecBitmap]) -> usize {
    HEADER_LEN
        + rows.len() * DIR_ENTRY_LEN
        + rows.iter().map(CodecBitmap::serialized_bytes).sum::<usize>()
        + 4
}

/// Serialize a segment to its byte image.
pub(crate) fn encode(id: u64, base: usize, rows: &[CodecBitmap]) -> Vec<u8> {
    let nbits = rows.first().map_or(0, CodecBitmap::len);
    debug_assert!(rows.iter().all(|r| r.len() == nbits), "ragged rows");
    let total = encoded_len(rows);
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(base as u64).to_le_bytes());
    out.extend_from_slice(&(nbits as u64).to_le_bytes());
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    // Directory: payloads start right after it.
    let mut offset = HEADER_LEN + rows.len() * DIR_ENTRY_LEN;
    for r in rows {
        let len = r.serialized_bytes();
        out.extend_from_slice(&(offset as u64).to_le_bytes());
        out.extend_from_slice(&(len as u32).to_le_bytes());
        offset += len;
    }
    for r in rows {
        r.write_bytes(&mut out);
    }
    debug_assert_eq!(out.len() + 4, total, "encoded_len drifted from encode");
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Write a segment file durably into `dir`; returns `(file_name, bytes)`.
pub(crate) fn write(
    dir: &Path,
    id: u64,
    base: usize,
    rows: &[CodecBitmap],
) -> Result<(String, u64)> {
    let bytes = encode(id, base, rows);
    let name = file_name(id);
    let tmp = dir.join(format!("{name}.tmp"));
    let final_path = dir.join(&name);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, &final_path)?;
    sync_dir(dir);
    Ok((name, bytes.len() as u64))
}

/// Best-effort directory fsync (makes the rename itself durable; not
/// supported on every platform, and recovery tolerates its absence).
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(f) = fs::File::open(dir) {
        let _ = f.sync_all();
    }
}

/// A segment-corruption error naming the offending file.
fn corrupt(path: &Path, detail: impl std::fmt::Display) -> StoreError {
    StoreError::Corrupt {
        what: "segment",
        detail: format!("{}: {detail}", path.display()),
    }
}

impl Segment {
    /// Load and fully validate a segment file: magic, whole-file CRC,
    /// directory consistency, then every row payload (which re-checks
    /// the codec-level structural invariants).
    pub(crate) fn load(path: &Path) -> Result<Segment> {
        let buf = fs::read(path)?;
        if buf.len() < HEADER_LEN + 4 {
            return Err(corrupt(
                path,
                format!("{} bytes is too short", buf.len()),
            ));
        }
        if &buf[..8] != MAGIC {
            return Err(corrupt(path, "bad magic"));
        }
        let (body, tail) = buf.split_at(buf.len() - 4);
        let stored_crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        if crc32(body) != stored_crc {
            return Err(corrupt(path, "checksum mismatch"));
        }
        let mut pos = 8usize;
        let id = read_u64(body, &mut pos).map_err(|e| corrupt(path, e))?;
        let base =
            read_u64(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
        let nbits =
            read_u64(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
        let m = read_u32(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
        let dir_bytes = m
            .checked_mul(DIR_ENTRY_LEN)
            .and_then(|d| HEADER_LEN.checked_add(d))
            .ok_or_else(|| corrupt(path, format!("row count {m} overflows")))?;
        if dir_bytes > body.len() {
            return Err(corrupt(path, format!("directory of {m} rows truncated")));
        }
        let mut rows = Vec::with_capacity(m);
        let mut expected_offset = dir_bytes;
        for i in 0..m {
            let offset =
                read_u64(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
            let len =
                read_u32(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
            if offset != expected_offset {
                return Err(corrupt(
                    path,
                    format!("row {i} offset {offset}, expected {expected_offset}"),
                ));
            }
            let end = offset
                .checked_add(len)
                .filter(|&e| e <= body.len())
                .ok_or_else(|| {
                    corrupt(path, format!("row {i} overruns the file"))
                })?;
            let mut rpos = offset;
            let row = CodecBitmap::read_bytes(body, &mut rpos)
                .map_err(|e| corrupt(path, format!("row {i}: {e}")))?;
            if rpos != end {
                return Err(corrupt(
                    path,
                    format!(
                        "row {i} consumed {} of {len} directory bytes",
                        rpos - offset
                    ),
                ));
            }
            if row.len() != nbits {
                return Err(corrupt(
                    path,
                    format!("row {i} is {} bits, segment holds {nbits}", row.len()),
                ));
            }
            rows.push(row);
            expected_offset = end;
        }
        if expected_offset != body.len() {
            return Err(corrupt(
                path,
                format!(
                    "{} trailing bytes after the last row",
                    body.len() - expected_offset
                ),
            ));
        }
        let file = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default()
            .to_string();
        Ok(Segment { id, file, base, nbits, bytes: buf.len() as u64, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bic::bitmap::Bitmap;
    use crate::substrate::rng::Xoshiro256;

    fn rows_for(n: usize, seed: u64) -> Vec<CodecBitmap> {
        let mut rng = Xoshiro256::seeded(seed);
        let dense: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
        let mut clustered = Bitmap::zeros(n);
        let mut i = 0;
        while i + 40 < n {
            for j in i..i + 20 {
                clustered.set(j, true);
            }
            i += 600;
        }
        let mut sparse = Bitmap::zeros(n);
        for _ in 0..n / 512 {
            sparse.set(rng.next_below(n.max(1) as u64) as usize, true);
        }
        vec![
            CodecBitmap::from_bitmap(&Bitmap::from_bools(&dense)),
            CodecBitmap::from_bitmap(&clustered),
            CodecBitmap::from_bitmap(&sparse),
            CodecBitmap::from_bitmap(&Bitmap::zeros(n)), // empty row
        ]
    }

    #[test]
    fn write_load_roundtrip_and_exact_length() {
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for n in [0usize, 65, 10_007, 70_000] {
            let rows = rows_for(n, n as u64 + 1);
            let (name, bytes) = write(&dir, 7, 1234, &rows).unwrap();
            assert_eq!(bytes as usize, encoded_len(&rows), "n={n}");
            let seg = Segment::load(&dir.join(&name)).unwrap();
            assert_eq!(seg.id, 7);
            assert_eq!(seg.base, 1234);
            assert_eq!(seg.nbits, n);
            assert_eq!(seg.bytes, bytes);
            assert_eq!(seg.rows, rows, "representational row equality n={n}");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_corruption_at_every_byte() {
        let rows = rows_for(2_000, 99);
        let image = encode(3, 0, &rows);
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-corrupt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg-x.bic");
        // Truncations: every proper prefix must fail cleanly.
        for cut in (0..image.len()).step_by(7).chain([image.len() - 1]) {
            fs::write(&path, &image[..cut]).unwrap();
            assert!(Segment::load(&path).is_err(), "cut at {cut}");
        }
        // Bit flips: every byte is covered by the CRC.
        let mut copy = image.clone();
        for i in (0..copy.len()).step_by(11) {
            copy[i] ^= 0x40;
            fs::write(&path, &copy).unwrap();
            assert!(Segment::load(&path).is_err(), "flip at {i}");
            copy[i] ^= 0x40;
        }
        // The pristine image still loads.
        fs::write(&path, &image).unwrap();
        assert!(Segment::load(&path).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }
}
