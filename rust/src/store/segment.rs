//! Immutable segment files — the durable unit of the store.
//!
//! Current layout, version 2 (all little-endian):
//!
//! ```text
//! [ 0.. 8)  magic  b"BICSEG2\0"
//! [ 8..16)  id     u64   segment id (manifest cross-check)
//! [16..24)  base   u64   first global object id this segment covers
//! [24..32)  nbits  u64   objects (bits per row)
//! [32..36)  m      u32   attribute row count
//! [36..36+20m)    row directory: m x { offset u64, len u32, card u64 }
//!                 (absolute file offset + byte length of each payload,
//!                  plus the row's exact cardinality — the zone map)
//! [.. ]     payloads: m codec-tagged rows (CodecBitmap::write_bytes)
//! [-4..]    crc32 over every preceding byte
//! ```
//!
//! The per-row `card` column is the segment's [`ZoneMap`]: queries use
//! it to skip segments that cannot contribute (see [`super::zone`]).
//! Version-1 files (`b"BICSEG1\0"`, 12-byte directory entries, no
//! cards) still load — they just carry no zone map, which the
//! evaluator treats as "unknown, never skip". Loading a v2 file
//! re-verifies every stored cardinality against the decoded row, so a
//! zone map can never silently disagree with the bits it summarizes.
//!
//! Version 3 (`b"BICSEG3\0"`) is the v2 layout with one optional
//! section appended between the payloads and the CRC: the chunk's
//! bit-sliced index ([`SegmentBsi`], PERF.md §bit-sliced-tier),
//! written when the store carries a BSI layout. v1/v2 files still load
//! with `bsi: None` — the slice-circuit tier simply falls back to
//! OR-expansion over them — and a loaded v3 section is rebuild-verified
//! against the decoded rows (same discipline as the zone cards), so
//! lying slices quarantine the segment instead of corrupting range
//! results.
//!
//! Write protocol: serialize fully in memory, write to `<name>.tmp`,
//! fsync, rename into place, fsync the directory. A segment file is
//! referenced by the manifest only after this completes, so a torn
//! segment write can only ever be an orphan — recovery deletes it and
//! the WAL still covers its batches. The trailing CRC additionally
//! catches in-place corruption of committed files at load time.

use std::path::Path;

use super::vfs::Vfs;
use super::zone::ZoneMap;
use super::{Result, StoreError};
use crate::bic::codec::{read_u32, read_u64, CodecBitmap};
use crate::bsi::{self, BsiLayout, SegmentBsi};
use crate::substrate::crc::crc32;

/// Version-2 magic (zone-mapped directory).
pub(crate) const MAGIC: &[u8; 8] = b"BICSEG2\0";
/// Version-1 magic (pre-zone-map files; still loadable).
pub(crate) const MAGIC_V1: &[u8; 8] = b"BICSEG1\0";
/// Version-3 magic (v2 plus the trailing bit-sliced-index section).
pub(crate) const MAGIC_V3: &[u8; 8] = b"BICSEG3\0";
const HEADER_LEN: usize = 36;
const DIR_ENTRY_LEN: usize = 20;
const DIR_ENTRY_LEN_V1: usize = 12;

/// A loaded (or just-written) segment: metadata + compressed rows in
/// memory. Rows stay in their codec encodings; the reader streams them
/// into query accumulators without decompressing the set.
pub struct Segment {
    pub(crate) id: u64,
    /// File name within the store directory.
    pub(crate) file: String,
    /// First global object id.
    pub(crate) base: usize,
    /// Objects (bits per row).
    pub(crate) nbits: usize,
    /// On-disk size in bytes.
    pub(crate) bytes: u64,
    /// One compressed row per attribute.
    pub(crate) rows: Vec<CodecBitmap>,
    /// Per-row cardinalities (`None` for version-1 files — unknown,
    /// never used to skip).
    pub(crate) zone: Option<ZoneMap>,
    /// The chunk's bit-sliced index (`None` for v1/v2 files or stores
    /// without a BSI layout — the range tier falls back there).
    pub(crate) bsi: Option<SegmentBsi>,
}

/// File name for segment `id`.
pub(crate) fn file_name(id: u64) -> String {
    format!("seg-{id:08}.bic")
}

/// Exact on-disk byte size of a segment wrapping `rows` — what the
/// scheduler's durable tier charges the extmem channel per result,
/// without serializing anything.
pub fn encoded_len(rows: &[CodecBitmap]) -> usize {
    HEADER_LEN
        + rows.len() * DIR_ENTRY_LEN
        + rows.iter().map(CodecBitmap::serialized_bytes).sum::<usize>()
        + 4
}

/// Serialize a segment to its byte image; `zone` must have been
/// measured over exactly these `rows`, and `bsi` (when present — it
/// selects the v3 magic) built over exactly these `rows`.
pub(crate) fn encode(
    id: u64,
    base: usize,
    rows: &[CodecBitmap],
    zone: &ZoneMap,
    bsi: Option<&SegmentBsi>,
) -> Vec<u8> {
    let nbits = rows.first().map_or(0, CodecBitmap::len);
    debug_assert!(rows.iter().all(|r| r.len() == nbits), "ragged rows");
    debug_assert_eq!(zone.num_attrs(), rows.len(), "zone map width");
    let total = encoded_len(rows)
        + bsi.map_or(0, SegmentBsi::serialized_bytes);
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(if bsi.is_some() { MAGIC_V3 } else { MAGIC });
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(base as u64).to_le_bytes());
    out.extend_from_slice(&(nbits as u64).to_le_bytes());
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    // Directory: payloads start right after it.
    let mut offset = HEADER_LEN + rows.len() * DIR_ENTRY_LEN;
    for (a, r) in rows.iter().enumerate() {
        let len = r.serialized_bytes();
        out.extend_from_slice(&(offset as u64).to_le_bytes());
        out.extend_from_slice(&(len as u32).to_le_bytes());
        out.extend_from_slice(&zone.card(a).to_le_bytes());
        offset += len;
    }
    for r in rows {
        r.write_bytes(&mut out);
    }
    if let Some(b) = bsi {
        b.write_bytes(&mut out);
    }
    debug_assert_eq!(out.len() + 4, total, "encoded_len drifted from encode");
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Write a segment file durably into `dir`; returns
/// `(file_name, bytes, zone_map, bsi)` — the zone map (and, when a
/// layout is given, the bit-sliced section) is measured here so the
/// in-memory [`Segment`] and the on-disk image always agree.
pub(crate) fn write(
    vfs: &dyn Vfs,
    dir: &Path,
    id: u64,
    base: usize,
    rows: &[CodecBitmap],
    layout: Option<&BsiLayout>,
) -> Result<(String, u64, ZoneMap, Option<SegmentBsi>)> {
    let zone = ZoneMap::from_rows(rows);
    let bsi = layout.map(|l| bsi::build_chunk(l, rows));
    let bytes = encode(id, base, rows, &zone, bsi.as_ref());
    let name = file_name(id);
    let tmp = dir.join(format!("{name}.tmp"));
    let final_path = dir.join(&name);
    {
        let mut f = vfs.create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync()?;
    }
    vfs.rename(&tmp, &final_path)?;
    sync_dir(vfs, dir);
    Ok((name, bytes.len() as u64, zone, bsi))
}

/// Best-effort directory fsync (makes the rename itself durable; not
/// supported on every platform, and recovery tolerates its absence).
pub(crate) fn sync_dir(vfs: &dyn Vfs, dir: &Path) {
    let _ = vfs.sync_dir(dir);
}

/// A segment-corruption error naming the offending file.
fn corrupt(path: &Path, detail: impl std::fmt::Display) -> StoreError {
    StoreError::Corrupt {
        what: "segment",
        detail: format!("{}: {detail}", path.display()),
    }
}

impl Segment {
    /// Load and fully validate a segment file: magic (v1 or v2),
    /// whole-file CRC, directory consistency, then every row payload
    /// (which re-checks the codec-level structural invariants). For v2
    /// files the stored cardinalities are re-verified against the
    /// decoded rows, so a loaded zone map is always exact.
    pub(crate) fn load(vfs: &dyn Vfs, path: &Path) -> Result<Segment> {
        let buf = vfs.read(path)?;
        if buf.len() < HEADER_LEN + 4 {
            return Err(corrupt(
                path,
                format!("{} bytes is too short", buf.len()),
            ));
        }
        let (zoned, sliced) = match &buf[..8] {
            m if m == MAGIC_V3 => (true, true),
            m if m == MAGIC => (true, false),
            m if m == MAGIC_V1 => (false, false),
            _ => return Err(corrupt(path, "bad magic")),
        };
        let entry_len = if zoned { DIR_ENTRY_LEN } else { DIR_ENTRY_LEN_V1 };
        let (body, tail) = buf.split_at(buf.len() - 4);
        let stored_crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        if crc32(body) != stored_crc {
            return Err(corrupt(path, "checksum mismatch"));
        }
        let mut pos = 8usize;
        let id = read_u64(body, &mut pos).map_err(|e| corrupt(path, e))?;
        let base =
            read_u64(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
        let nbits =
            read_u64(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
        let m = read_u32(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
        let dir_bytes = m
            .checked_mul(entry_len)
            .and_then(|d| HEADER_LEN.checked_add(d))
            .ok_or_else(|| corrupt(path, format!("row count {m} overflows")))?;
        if dir_bytes > body.len() {
            return Err(corrupt(path, format!("directory of {m} rows truncated")));
        }
        let mut rows = Vec::with_capacity(m);
        let mut cards = Vec::with_capacity(if zoned { m } else { 0 });
        let mut expected_offset = dir_bytes;
        for i in 0..m {
            let offset =
                read_u64(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
            let len =
                read_u32(body, &mut pos).map_err(|e| corrupt(path, e))? as usize;
            if zoned {
                cards.push(
                    read_u64(body, &mut pos).map_err(|e| corrupt(path, e))?,
                );
            }
            if offset != expected_offset {
                return Err(corrupt(
                    path,
                    format!("row {i} offset {offset}, expected {expected_offset}"),
                ));
            }
            let end = offset
                .checked_add(len)
                .filter(|&e| e <= body.len())
                .ok_or_else(|| {
                    corrupt(path, format!("row {i} overruns the file"))
                })?;
            let mut rpos = offset;
            let row = CodecBitmap::read_bytes(body, &mut rpos)
                .map_err(|e| corrupt(path, format!("row {i}: {e}")))?;
            if rpos != end {
                return Err(corrupt(
                    path,
                    format!(
                        "row {i} consumed {} of {len} directory bytes",
                        rpos - offset
                    ),
                ));
            }
            if row.len() != nbits {
                return Err(corrupt(
                    path,
                    format!("row {i} is {} bits, segment holds {nbits}", row.len()),
                ));
            }
            if zoned && cards[i] != row.count_ones() as u64 {
                return Err(corrupt(
                    path,
                    format!(
                        "row {i} zone cardinality {} disagrees with the row \
                         ({} set bits)",
                        cards[i],
                        row.count_ones()
                    ),
                ));
            }
            rows.push(row);
            expected_offset = end;
        }
        let bsi = if sliced {
            let mut bpos = expected_offset;
            let section = SegmentBsi::read_bytes(body, &mut bpos, nbits)
                .map_err(|e| corrupt(path, format!("bsi section: {e}")))?;
            // The re-verify discipline of the zone cards, extended: a
            // decoded slice set that disagrees with the rows it claims
            // to index is corruption, not a soft fallback.
            section
                .verify(&rows)
                .map_err(|e| corrupt(path, format!("bsi section: {e}")))?;
            expected_offset = bpos;
            Some(section)
        } else {
            None
        };
        if expected_offset != body.len() {
            return Err(corrupt(
                path,
                format!(
                    "{} trailing bytes after the last row",
                    body.len() - expected_offset
                ),
            ));
        }
        let file = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default()
            .to_string();
        let zone = zoned.then(|| ZoneMap::from_cards(cards));
        Ok(Segment {
            id,
            file,
            base,
            nbits,
            bytes: buf.len() as u64,
            rows,
            zone,
            bsi,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::vfs::RealVfs;
    use super::*;
    use crate::bic::bitmap::Bitmap;
    use crate::substrate::rng::Xoshiro256;
    use std::fs;

    fn rows_for(n: usize, seed: u64) -> Vec<CodecBitmap> {
        let mut rng = Xoshiro256::seeded(seed);
        let dense: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
        let mut clustered = Bitmap::zeros(n);
        let mut i = 0;
        while i + 40 < n {
            for j in i..i + 20 {
                clustered.set(j, true);
            }
            i += 600;
        }
        let mut sparse = Bitmap::zeros(n);
        for _ in 0..n / 512 {
            sparse.set(rng.next_below(n.max(1) as u64) as usize, true);
        }
        vec![
            CodecBitmap::from_bitmap(&Bitmap::from_bools(&dense)),
            CodecBitmap::from_bitmap(&clustered),
            CodecBitmap::from_bitmap(&sparse),
            CodecBitmap::from_bitmap(&Bitmap::zeros(n)), // empty row
        ]
    }

    /// Hand-encode the version-1 layout (12-byte directory entries, no
    /// cards) — the compatibility corpus for pre-zone-map stores.
    fn encode_v1(id: u64, base: usize, rows: &[CodecBitmap]) -> Vec<u8> {
        let nbits = rows.first().map_or(0, CodecBitmap::len);
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_V1);
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&(base as u64).to_le_bytes());
        out.extend_from_slice(&(nbits as u64).to_le_bytes());
        out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
        let mut offset = HEADER_LEN + rows.len() * DIR_ENTRY_LEN_V1;
        for r in rows {
            let len = r.serialized_bytes();
            out.extend_from_slice(&(offset as u64).to_le_bytes());
            out.extend_from_slice(&(len as u32).to_le_bytes());
            offset += len;
        }
        for r in rows {
            r.write_bytes(&mut out);
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    #[test]
    fn write_load_roundtrip_and_exact_length() {
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for n in [0usize, 65, 10_007, 70_000] {
            let rows = rows_for(n, n as u64 + 1);
            let (name, bytes, zone, bsi) =
                write(&RealVfs, &dir, 7, 1234, &rows, None).unwrap();
            assert!(bsi.is_none(), "no layout, no section");
            assert_eq!(bytes as usize, encoded_len(&rows), "n={n}");
            let seg = Segment::load(&RealVfs, &dir.join(&name)).unwrap();
            assert_eq!(seg.id, 7);
            assert_eq!(seg.base, 1234);
            assert_eq!(seg.nbits, n);
            assert_eq!(seg.bytes, bytes);
            assert_eq!(seg.rows, rows, "representational row equality n={n}");
            // The zone map round-trips exactly and matches the rows.
            assert_eq!(seg.zone.as_ref(), Some(&zone), "n={n}");
            for (a, r) in rows.iter().enumerate() {
                assert_eq!(zone.card(a), r.count_ones() as u64, "n={n} row {a}");
            }
            assert!(zone.is_zero(3), "the all-zeros row is zone-zero");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_files_load_without_a_zone_map() {
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-v1-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let rows = rows_for(3_000, 42);
        let image = encode_v1(9, 512, &rows);
        let path = dir.join("seg-v1.bic");
        fs::write(&path, &image).unwrap();
        let seg = Segment::load(&RealVfs, &path).unwrap();
        assert_eq!(seg.id, 9);
        assert_eq!(seg.base, 512);
        assert_eq!(seg.nbits, 3_000);
        assert_eq!(seg.rows, rows, "v1 rows decode identically");
        assert!(seg.zone.is_none(), "pre-zone-map file carries no map");
        let _ = fs::remove_dir_all(&dir);
    }

    /// A single-valued column layout + matching rows: record `j` takes
    /// value index `j % nvals` when `j % 5 != 0` (some records lack
    /// the column).
    fn bsi_fixture(
        n: usize,
        values: &[i64],
    ) -> (crate::bsi::BsiLayout, Vec<CodecBitmap>) {
        let rows = (0..values.len())
            .map(|i| {
                let mut b = Bitmap::zeros(n);
                for j in 0..n {
                    if j % 5 != 0 && j % values.len() == i {
                        b.set(j, true);
                    }
                }
                CodecBitmap::from_bitmap(&b)
            })
            .collect();
        let layout = crate::bsi::BsiLayout::new(vec![crate::bsi::BsiColSpec {
            name: "v".into(),
            attr_lo: 0,
            values: values.to_vec(),
        }]);
        (layout, rows)
    }

    #[test]
    fn v3_files_round_trip_the_bsi_section() {
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-v3-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let (layout, rows) = bsi_fixture(1_200, &[3, 7, 11, 20]);
        let (name, bytes, _, bsi) =
            write(&RealVfs, &dir, 4, 0, &rows, Some(&layout)).unwrap();
        let bsi = bsi.expect("layout given, section built");
        assert!(bsi.cols[0].col.is_some(), "fixture is single-valued");
        assert_eq!(
            bytes as usize,
            encoded_len(&rows) + bsi.serialized_bytes()
        );
        let seg = Segment::load(&RealVfs, &dir.join(&name)).unwrap();
        assert_eq!(seg.bsi.as_ref(), Some(&bsi), "section round-trips");
        assert!(seg.zone.is_some(), "v3 still carries the zone map");
        assert_eq!(seg.rows, rows);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_a_lying_bsi_section_even_with_a_valid_crc() {
        let (layout, rows) = bsi_fixture(900, &[1, 2, 5]);
        let bsi = crate::bsi::build_chunk(&layout, &rows);
        let mut lying = bsi.clone();
        if let Some(c) = &mut lying.cols[0].col {
            let mut b = c.slices[0].to_bitmap();
            b.set(6, !b.get(6));
            c.slices[0] = CodecBitmap::from_bitmap(&b);
        }
        let image =
            encode(2, 0, &rows, &ZoneMap::from_rows(&rows), Some(&lying));
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-bsilie-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg-bsilie.bic");
        fs::write(&path, &image).unwrap();
        let err = Segment::load(&RealVfs, &path).expect_err("lying slices");
        assert!(err.to_string().contains("bsi"), "{err}");
        // The honest section loads.
        let image =
            encode(2, 0, &rows, &ZoneMap::from_rows(&rows), Some(&bsi));
        fs::write(&path, &image).unwrap();
        assert!(Segment::load(&RealVfs, &path).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_corruption_at_every_byte() {
        let rows = rows_for(2_000, 99);
        let image = encode(3, 0, &rows, &ZoneMap::from_rows(&rows), None);
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-corrupt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg-x.bic");
        // Truncations: every proper prefix must fail cleanly.
        for cut in (0..image.len()).step_by(7).chain([image.len() - 1]) {
            fs::write(&path, &image[..cut]).unwrap();
            assert!(Segment::load(&RealVfs, &path).is_err(), "cut at {cut}");
        }
        // Bit flips: every byte is covered by the CRC.
        let mut copy = image.clone();
        for i in (0..copy.len()).step_by(11) {
            copy[i] ^= 0x40;
            fs::write(&path, &copy).unwrap();
            assert!(Segment::load(&RealVfs, &path).is_err(), "flip at {i}");
            copy[i] ^= 0x40;
        }
        // The pristine image still loads.
        fs::write(&path, &image).unwrap();
        assert!(Segment::load(&RealVfs, &path).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_a_lying_zone_map_even_with_a_valid_crc() {
        let rows = rows_for(1_500, 7);
        let mut image =
            encode(1, 0, &rows, &ZoneMap::from_rows(&rows), None);
        // Patch row 0's stored cardinality (directory entry bytes
        // 36+8+4 .. 36+20) and re-stamp the CRC so only the semantic
        // check can catch the lie.
        let card_at = HEADER_LEN + 12;
        let lied = (rows[0].count_ones() as u64 + 1).to_le_bytes();
        image[card_at..card_at + 8].copy_from_slice(&lied);
        let body_len = image.len() - 4;
        let crc = crc32(&image[..body_len]).to_le_bytes();
        image[body_len..].copy_from_slice(&crc);
        let dir = std::env::temp_dir()
            .join(format!("bic-seg-zonelie-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg-lie.bic");
        fs::write(&path, &image).unwrap();
        let err = Segment::load(&RealVfs, &path).expect_err("lying zone map");
        assert!(err.to_string().contains("zone"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
