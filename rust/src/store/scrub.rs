//! The scrubber: proactive re-verification of segments *from disk*.
//!
//! Loading a segment runs the full validation stack (magic, whole-file
//! CRC, directory consistency, codec structural invariants, zone-map
//! cardinality cross-checks) — but only at open time. A store that runs
//! for weeks serves queries from memory while the files underneath rot
//! silently. [`Store::scrub`] re-reads every live segment through the
//! store's [`Vfs`](super::Vfs), re-runs that whole stack, and
//! **quarantines** what fails (manifest tombstone + move to
//! `quarantined/`) instead of leaving the damage to ambush the next
//! recovery. [`Scrubber`] runs the same pass on a schedule, mirroring
//! the background [`Compactor`](super::Compactor).
//!
//! Quarantining is the *detection* half of degraded operation; what
//! reads do about it is the [`DegradedPolicy`](super::DegradedPolicy)
//! knob — the scrubber itself always prefers tombstoning to panicking,
//! under either policy. Real I/O failures that are not damage verdicts
//! (e.g. permissions) abort the pass with a typed error instead of
//! quarantining good data.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::manifest::{self, ManifestState, SegmentEntry};
use super::segment::Segment;
use super::{move_to_quarantine, Result, Store, StoreError};
use crate::bic::clock;
use crate::obs::{TraceOp, TraceStage};

/// What one scrub pass found and did.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Live segments re-verified from disk this pass.
    pub segments_checked: usize,
    /// Bytes read and checksum-verified.
    pub bytes_verified: u64,
    /// Files quarantined *by this pass* (manifest tombstoned + moved).
    pub quarantined: Vec<String>,
    /// Total quarantined segments after the pass (incl. prior passes).
    pub degraded_segments: usize,
    /// Total objects inside quarantined ranges after the pass.
    pub rows_unavailable: usize,
}

impl Store {
    /// One scrub pass: re-load every live segment from disk, verify it
    /// end to end, and quarantine the ones that fail (or vanished).
    /// Returns what was checked and what was tombstoned; the store
    /// keeps serving throughout — a quarantined segment's range simply
    /// becomes a hole under [`super::DegradedPolicy::ServeHealthy`],
    /// or a typed refusal under
    /// [`super::DegradedPolicy::FailClosed`].
    pub fn scrub(&mut self) -> Result<ScrubReport> {
        let t0 = self.cfg.telemetry.as_ref().map(|_| Instant::now());
        let mut report = ScrubReport::default();
        let mut bad: Vec<usize> = Vec::new();
        for (i, seg) in self.segments.iter().enumerate() {
            let path = self.dir.join(&seg.file);
            match Segment::load(self.vfs(), &path) {
                Ok(on_disk) => {
                    // The disk copy must be the segment the manifest
                    // committed — same identity *and* same bits.
                    if on_disk.id == seg.id
                        && on_disk.base == seg.base
                        && on_disk.nbits == seg.nbits
                        && on_disk.rows == seg.rows
                    {
                        report.segments_checked += 1;
                        report.bytes_verified += on_disk.bytes;
                    } else {
                        bad.push(i);
                    }
                }
                // Damage verdicts quarantine; real I/O trouble aborts.
                Err(StoreError::Corrupt { .. }) => bad.push(i),
                Err(StoreError::Io(e))
                    if e.kind() == std::io::ErrorKind::NotFound =>
                {
                    bad.push(i)
                }
                Err(other) => return Err(other),
            }
        }
        if bad.is_empty() {
            report.degraded_segments = self.degraded_segments();
            report.rows_unavailable = self.rows_unavailable();
            self.note_scrub_pass(t0, &report);
            return Ok(report);
        }

        // Tombstone the failures: move files aside, flip the entries,
        // and commit the new truth in one manifest replace. The live
        // list shrinks only after the commit succeeds, so an error
        // leaves the in-memory store agreeing with the old manifest.
        let mut entries = self.manifest_entries();
        for &i in &bad {
            let seg = &self.segments[i];
            move_to_quarantine(self.vfs(), &self.dir, &seg.file);
            if let Some(e) = entries.iter_mut().find(|e| e.id == seg.id) {
                e.quarantined = true;
            }
            report.quarantined.push(seg.file.clone());
        }
        manifest::commit(
            self.vfs(),
            &self.dir,
            &ManifestState {
                num_attrs: self.num_attrs,
                next_segment_id: self.next_segment_id,
                wal_gen: self.wal_gen,
                segments: entries,
            },
        )?;
        for &i in bad.iter().rev() {
            let seg = self.segments.remove(i);
            self.quarantined.push(SegmentEntry {
                id: seg.id,
                file: seg.file.clone(),
                base: seg.base,
                nbits: seg.nbits,
                bytes: seg.bytes,
                quarantined: true,
            });
        }
        self.quarantined.sort_by_key(|e| e.base);
        report.degraded_segments = self.degraded_segments();
        report.rows_unavailable = self.rows_unavailable();
        self.note_scrub_pass(t0, &report);
        Ok(report)
    }

    /// Book one completed scrub pass: bump the always-on maintenance
    /// counters, and record the pass duration when telemetry is on.
    fn note_scrub_pass(&mut self, t0: Option<Instant>, report: &ScrubReport) {
        self.scrub_passes += 1;
        self.scrub_bytes_verified += report.bytes_verified;
        if let (Some(t), Some(t0)) = (self.cfg.telemetry.as_deref(), t0) {
            let dur = clock::to_cycles(t0.elapsed());
            t.scrub.record(dur);
            t.ring.push(
                TraceOp::Scrub,
                TraceStage::Run,
                dur,
                report.bytes_verified,
            );
        }
    }
}

/// A background scrubbing thread over a shared store handle — one
/// [`Store::scrub`] pass per tick; stops on [`Scrubber::stop`] or drop.
pub struct Scrubber {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Scrubber {
    /// Spawn the scrubber, running a pass every `interval`.
    pub fn spawn(store: Arc<Mutex<Store>>, interval: Duration) -> Scrubber {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                // A poisoned store lock means a writer panicked
                // mid-mutation: stop scrubbing rather than judge
                // possibly-torn state.
                let Ok(mut guard) = store.lock() else { break };
                // Damage found is handled (quarantined) inside scrub;
                // an abort (real I/O failure) retries next tick — the
                // foreground surfaces such errors on its own calls.
                let _ = guard.scrub();
            }
        });
        Scrubber { stop, handle: Some(handle) }
    }

    /// Stop and join the background thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scrubber {
    fn drop(&mut self) {
        self.shutdown();
    }
}
