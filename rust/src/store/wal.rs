//! Write-ahead log: the durability of the memtable between segment
//! flushes — now with **group commit**.
//!
//! One file per WAL *generation* (`wal-<gen>.log`); each flush commits a
//! new generation through the manifest, so replay can never double-count
//! a batch that already lives in a segment — the crash window between
//! "manifest committed" and "old WAL deleted" leaves only an orphan file
//! that recovery ignores.
//!
//! Record framing (little-endian):
//!
//! ```text
//! u32 len | u32 crc32(payload) | payload[len]
//! payload = u32 row_count, then row_count x CodecBitmap::write_bytes
//! ```
//!
//! ## Group commit (leader/follower)
//!
//! Appends are split into a cheap **submit** (frame the record, buffer
//! it, take a sequence number — `Wal::submit` returns an
//! [`AppendTicket`]) and a blocking **wait** ([`AppendTicket::wait`] —
//! the durability acknowledgement). The first waiter whose record is
//! not yet durable becomes the *leader*: it takes the whole pending
//! buffer, writes it with one `write_all`, fsyncs once, marks every
//! covered sequence durable, and wakes the *followers* — so `k`
//! concurrent appends cost one fsync, not `k`. Submissions that arrive
//! while a leader is mid-sync buffer up and ride the next sync. An
//! optional batching `window` bounds the extra latency a waiter will
//! spend hoping for co-travellers before leading a sync itself
//! (`Duration::ZERO`, the default, syncs immediately).
//!
//! Because submit order assigns both the sequence number and the byte
//! position in the pending buffer, **ack order always matches WAL
//! record order** (property-tested in `rust/tests/store_props.rs`).
//!
//! ## Failure semantics
//!
//! A failed group **write** poisons the handle immediately: an unknown
//! prefix of the batch may be in the file, the tail is untrustworthy,
//! and re-writing would duplicate records — every subsequent
//! submit/wait errors until the store is reopened (recovery truncates
//! the torn tail). A failed **fsync** is retried while the failure
//! class is transient ([`io::ErrorKind::Interrupted`] / `WouldBlock` /
//! `TimedOut`), with bounded doubling backoff — the batch bytes are
//! already staged in order, only the durability barrier failed, so a
//! retry cannot reorder or duplicate anything. Retries exhausted (or a
//! hard failure class) poisons the generation like a failed write.
//!
//! All file I/O goes through the store's [`Vfs`] seam, so every one of
//! these failure paths is exercised deterministically by `FaultVfs`
//! plans (see `store::vfs`).
//!
//! Replay walks records until the first short, checksum-invalid, or
//! structurally invalid record and returns the prefix — exactly the set
//! of appends whose fsync completed. Torn tails at *any* byte offset
//! therefore recover to a prefix-consistent memtable (property-tested in
//! `rust/tests/store_props.rs`).

use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::vfs::{Vfs, VfsFile};
use super::{Result, StoreError};
use crate::bic::clock;
use crate::bic::codec::{read_u32, CodecBitmap};
use crate::obs::{Telemetry, TraceOp, TraceStage};
use crate::substrate::crc::crc32;

/// How many times a transiently-failing group fsync is retried before
/// the generation is poisoned.
const SYNC_RETRIES: u32 = 3;

/// First retry backoff (doubles per attempt).
const SYNC_BACKOFF: Duration = Duration::from_millis(1);

/// Fsync failure classes worth retrying: the call may simply be
/// re-issued. Anything else (I/O error, ENOSPC, injected hard failure)
/// is treated as media/filesystem trouble and poisons the generation.
fn transient(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::Interrupted
            | io::ErrorKind::WouldBlock
            | io::ErrorKind::TimedOut
    )
}

/// File name of WAL generation `gen`.
pub(crate) fn file_name(gen: u64) -> String {
    format!("wal-{gen:08}.log")
}

/// Path of WAL generation `gen` inside `dir`.
pub(crate) fn path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(file_name(gen))
}

/// An open, append-only WAL handle with a group-commit core; tickets
/// hold `Arc` references into the same commit state, so they stay
/// valid (and waitable) after the store rotates to a new generation.
pub(crate) struct Wal {
    shared: Arc<Shared>,
}

struct Shared {
    /// How long a would-be leader waits for co-travellers before
    /// syncing (bounds added ack latency; zero = sync immediately).
    window: Duration,
    /// The log file. Separate from `state` so submissions keep landing
    /// in the pending buffer while the leader is inside `fsync`.
    file: Mutex<Box<dyn VfsFile>>,
    state: Mutex<CommitState>,
    cv: Condvar,
    /// When set, each successful leader write+fsync records its
    /// duration (and the group's byte size) here.
    obs: Option<Arc<Telemetry>>,
}

struct CommitState {
    /// Framed records submitted but not yet written+fsynced.
    pending: Vec<u8>,
    /// Next sequence number to hand out (sequences start at 1).
    next_seq: u64,
    /// Every sequence `<= durable` is fsynced.
    durable: u64,
    /// A leader is currently mid write+fsync.
    syncing: bool,
    /// A group write failed; the tail of the file is untrustworthy.
    poisoned: Option<String>,
}

/// A submitted-but-not-yet-durable WAL append. [`AppendTicket::wait`]
/// blocks until the record is fsynced (riding a group commit when other
/// appends are in flight) and is the store's durability
/// acknowledgement.
#[must_use = "an append is only durable once the ticket has been waited on"]
pub struct AppendTicket {
    shared: Arc<Shared>,
    seq: u64,
}

impl AppendTicket {
    /// Block until this append's record is durable (fsynced). `Ok` is
    /// the durability acknowledgement; an error means the record — and
    /// every later submission to this generation — is lost.
    pub fn wait(self) -> Result<()> {
        self.shared.wait_durable(self.seq, true)
    }
}

impl Shared {
    /// The commit-state lock, with panic-poisoning mapped to a typed
    /// error instead of a propagated panic.
    fn state(&self) -> Result<MutexGuard<'_, CommitState>> {
        self.state.lock().map_err(|_| StoreError::Poisoned("wal commit state"))
    }

    /// Block until `seq` is durable. `allow_window` enables the
    /// batching wait; drains that already know no co-traveller can
    /// arrive (`sync_pending` under `&mut Store`) pass `false` and
    /// lead immediately.
    fn wait_durable(&self, seq: u64, allow_window: bool) -> Result<()> {
        let mut st = self.state()?;
        // Batching window: before leading a sync ourselves, give other
        // writers up to `window` to join it (bounded added latency).
        if allow_window
            && !self.window.is_zero()
            && st.durable < seq
            && st.poisoned.is_none()
            && !st.syncing
        {
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, self.window)
                .map_err(|_| StoreError::Poisoned("wal commit state"))?;
            st = guard;
        }
        loop {
            if st.durable >= seq {
                return Ok(());
            }
            if let Some(e) = &st.poisoned {
                return Err(StoreError::Invalid(format!(
                    "wal append lost to an earlier group-sync failure: {e}"
                )));
            }
            if st.syncing {
                st = self
                    .cv
                    .wait(st)
                    .map_err(|_| StoreError::Poisoned("wal commit state"))?;
                continue;
            }
            // Leader: take everything pending and sync it in one shot.
            // Invariant: bytes for every sequence in (durable, next_seq)
            // sit in `pending` whenever no leader is in flight, so the
            // take covers `seq`.
            let batch = std::mem::take(&mut st.pending);
            let high = st.next_seq - 1;
            st.syncing = true;
            drop(st);
            let res = self.write_and_sync(&batch);
            st = self.state()?;
            st.syncing = false;
            match res {
                Ok(()) => {
                    st.durable = st.durable.max(high);
                    self.cv.notify_all();
                    // Loop re-checks: `high >= seq`, so this returns Ok.
                }
                Err(e) => {
                    st.poisoned = Some(e.to_string());
                    self.cv.notify_all();
                    return Err(e.into());
                }
            }
        }
    }

    /// One group write + fsync. The write phase never retries — after
    /// a failed `write_all` an unknown prefix of the batch is already
    /// in the file, and re-writing would duplicate records. The sync
    /// phase retries transient failure classes with bounded doubling
    /// backoff: the bytes are staged, only the barrier failed, so
    /// re-issuing the fsync is safe.
    fn write_and_sync(&self, batch: &[u8]) -> io::Result<()> {
        let t0 = self.obs.as_ref().map(|_| Instant::now());
        let mut f = self
            .file
            .lock()
            .map_err(|_| io::Error::other("wal file lock poisoned"))?;
        f.write_all(batch)?;
        let mut delay = SYNC_BACKOFF;
        let mut attempt = 0u32;
        loop {
            match f.sync() {
                Ok(()) => {
                    if let (Some(t), Some(t0)) = (self.obs.as_deref(), t0) {
                        let dur = clock::to_cycles(t0.elapsed());
                        t.wal_fsync.record(dur);
                        t.ring.push(
                            TraceOp::Wal,
                            TraceStage::GroupCommit,
                            dur,
                            batch.len() as u64,
                        );
                    }
                    return Ok(());
                }
                Err(e) if attempt < SYNC_RETRIES && transient(e.kind()) => {
                    attempt += 1;
                    std::thread::sleep(delay);
                    delay = delay.saturating_mul(2);
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Frame one batch record (length + checksum + payload).
fn encode_record(rows: &[CodecBitmap]) -> Vec<u8> {
    let body: usize = rows.iter().map(CodecBitmap::serialized_bytes).sum();
    let mut payload = Vec::with_capacity(4 + body);
    payload.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for r in rows {
        r.write_bytes(&mut payload);
    }
    let mut record = Vec::with_capacity(8 + payload.len());
    record.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    record.extend_from_slice(&crc32(&payload).to_le_bytes());
    record.extend_from_slice(&payload);
    record
}

impl Wal {
    fn from_file(
        file: Box<dyn VfsFile>,
        window: Duration,
        obs: Option<Arc<Telemetry>>,
    ) -> Wal {
        Wal {
            shared: Arc::new(Shared {
                window,
                file: Mutex::new(file),
                state: Mutex::new(CommitState {
                    pending: Vec::new(),
                    next_seq: 1,
                    durable: 0,
                    syncing: false,
                    poisoned: None,
                }),
                cv: Condvar::new(),
                obs,
            }),
        }
    }

    /// Create (or open for append) generation `gen`.
    pub(crate) fn create(
        vfs: &dyn Vfs,
        dir: &Path,
        gen: u64,
        window: Duration,
        obs: Option<Arc<Telemetry>>,
    ) -> Result<Wal> {
        let file = vfs.open_append(&path(dir, gen))?;
        Ok(Wal::from_file(file, window, obs))
    }

    /// Reopen generation `gen` truncated to its valid prefix (what
    /// replay measured), positioned for appending.
    pub(crate) fn open_truncated(
        vfs: &dyn Vfs,
        dir: &Path,
        gen: u64,
        valid_len: u64,
        window: Duration,
        obs: Option<Arc<Telemetry>>,
    ) -> Result<Wal> {
        let file = vfs.open_truncated(&path(dir, gen), valid_len)?;
        Ok(Wal::from_file(file, window, obs))
    }

    /// Buffer one batch record and take its commit sequence. Cheap (no
    /// I/O); the returned ticket's [`AppendTicket::wait`] is the
    /// durability point. Submit order = WAL record order = ack order.
    pub(crate) fn submit(&self, rows: &[CodecBitmap]) -> Result<AppendTicket> {
        let record = encode_record(rows);
        let mut st = self.shared.state()?;
        if let Some(e) = &st.poisoned {
            return Err(StoreError::Invalid(format!(
                "wal unusable after a group-sync failure: {e}"
            )));
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.pending.extend_from_slice(&record);
        Ok(AppendTicket { shared: Arc::clone(&self.shared), seq })
    }

    /// Append one batch record and fsync before returning — submit +
    /// immediate wait. Production callers go through
    /// [`super::Store::begin_append`] (which adds the memtable side);
    /// this stays as the unit tests' direct-drive entry.
    #[cfg(test)]
    pub(crate) fn append(&self, rows: &[CodecBitmap]) -> Result<()> {
        self.submit(rows)?.wait()
    }

    /// Drive every outstanding submission durable (leading a sync if
    /// needed, skipping the batching window — the caller holds the
    /// store exclusively, so no co-traveller can arrive). Flush calls
    /// this before rotating the generation, so a rotation can never
    /// strand an un-synced ticket.
    pub(crate) fn sync_pending(&self) -> Result<()> {
        let target = {
            let st = self.shared.state()?;
            st.next_seq - 1
        };
        self.shared.wait_durable(target, false)
    }
}

/// Replay generation `gen`: returns the durably-acknowledged batch
/// prefix and its byte length within the file. A missing file is an
/// empty log. Never errors on a torn/corrupt tail — that is the crash
/// case it exists for; only real I/O failures surface.
pub(crate) fn replay(
    vfs: &dyn Vfs,
    dir: &Path,
    gen: u64,
    num_attrs: usize,
) -> Result<(Vec<Vec<CodecBitmap>>, u64)> {
    let buf = match vfs.read(&path(dir, gen)) {
        Ok(b) => b,
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            return Ok((Vec::new(), 0));
        }
        Err(e) => return Err(e.into()),
    };
    let mut batches = Vec::new();
    let mut pos = 0usize;
    loop {
        let record_start = pos;
        let Some(rest) = buf.get(pos..) else { break };
        if rest.len() < 8 {
            break; // torn header
        }
        let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
        let crc = u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]);
        let Some(payload) = rest.get(8..8 + len) else {
            break; // torn payload
        };
        if crc32(payload) != crc {
            break; // corrupt tail
        }
        let Some(rows) = decode_batch(payload, num_attrs) else {
            break; // structurally invalid (treated like corruption)
        };
        batches.push(rows);
        pos = record_start + 8 + len;
    }
    Ok((batches, pos as u64))
}

/// Decode one record payload; `None` on any structural violation.
fn decode_batch(payload: &[u8], num_attrs: usize) -> Option<Vec<CodecBitmap>> {
    let mut pos = 0usize;
    let m = read_u32(payload, &mut pos).ok()? as usize;
    if m != num_attrs {
        return None;
    }
    let mut rows = Vec::with_capacity(m);
    for _ in 0..m {
        rows.push(CodecBitmap::read_bytes(payload, &mut pos).ok()?);
    }
    if pos != payload.len() {
        return None;
    }
    let nbits = rows.first().map_or(0, CodecBitmap::len);
    if rows.iter().any(|r| r.len() != nbits) {
        return None;
    }
    Some(rows)
}

#[cfg(test)]
mod tests {
    use super::super::vfs::{FaultKind, FaultSpec, FaultVfs, RealVfs};
    use super::*;
    use crate::bic::bitmap::Bitmap;
    use crate::substrate::rng::Xoshiro256;
    use std::fs;

    fn batch(n: usize, seed: u64) -> Vec<CodecBitmap> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..3)
            .map(|_| {
                let bools: Vec<bool> =
                    (0..n).map(|_| rng.chance(0.2)).collect();
                CodecBitmap::from_bitmap(&Bitmap::from_bools(&bools))
            })
            .collect()
    }

    #[test]
    fn append_replay_roundtrip_and_torn_tails() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let batches: Vec<_> = (0..4).map(|i| batch(500 + i, i as u64)).collect();
        {
            let wal = Wal::create(&RealVfs, &dir, 5, Duration::ZERO, None).unwrap();
            for b in &batches {
                wal.append(b).unwrap();
            }
        }
        let (replayed, len) = replay(&RealVfs, &dir, 5, 3).unwrap();
        assert_eq!(replayed, batches);
        let full = fs::read(path(&dir, 5)).unwrap();
        assert_eq!(len, full.len() as u64);

        // Truncate at every byte: replay must yield exactly the whole
        // records that survive, in order.
        let mut boundaries = vec![0u64];
        {
            let mut p = 0usize;
            while p < full.len() {
                let l = u32::from_le_bytes([
                    full[p],
                    full[p + 1],
                    full[p + 2],
                    full[p + 3],
                ]) as usize;
                p += 8 + l;
                boundaries.push(p as u64);
            }
        }
        for cut in 0..=full.len() {
            fs::write(path(&dir, 5), &full[..cut]).unwrap();
            let (got, valid) = replay(&RealVfs, &dir, 5, 3).unwrap();
            let expect_records =
                boundaries.iter().filter(|&&b| b <= cut as u64).count() - 1;
            assert_eq!(got.len(), expect_records, "cut at {cut}");
            assert_eq!(got, batches[..expect_records], "cut at {cut}");
            assert_eq!(valid, boundaries[expect_records], "cut at {cut}");
        }

        // Missing generation = empty log.
        let (none, len0) = replay(&RealVfs, &dir, 99, 3).unwrap();
        assert!(none.is_empty());
        assert_eq!(len0, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_middle_record_cuts_the_prefix_there() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-corrupt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let batches: Vec<_> = (0..3).map(|i| batch(400, 10 + i)).collect();
        {
            let wal = Wal::create(&RealVfs, &dir, 0, Duration::ZERO, None).unwrap();
            for b in &batches {
                wal.append(b).unwrap();
            }
        }
        let mut bytes = fs::read(path(&dir, 0)).unwrap();
        // Flip one payload byte of the second record.
        let rec0_len =
            u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        let rec1_start = 8 + rec0_len;
        bytes[rec1_start + 8 + 5] ^= 0xFF;
        fs::write(path(&dir, 0), &bytes).unwrap();
        let (got, valid) = replay(&RealVfs, &dir, 0, 3).unwrap();
        assert_eq!(got.len(), 1, "only the record before the corruption");
        assert_eq!(got[0], batches[0]);
        assert_eq!(valid as usize, rec1_start);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_truncated_resumes_appending() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-resume-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let b0 = batch(300, 77);
        let b1 = batch(301, 78);
        {
            let wal = Wal::create(&RealVfs, &dir, 1, Duration::ZERO, None).unwrap();
            wal.append(&b0).unwrap();
        }
        // Simulate a torn tail, then recover + append.
        let mut bytes = fs::read(path(&dir, 1)).unwrap();
        let good_len = bytes.len();
        bytes.extend_from_slice(&[1, 2, 3]); // garbage tail
        fs::write(path(&dir, 1), &bytes).unwrap();
        let (got, valid) = replay(&RealVfs, &dir, 1, 3).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(valid as usize, good_len);
        {
            let wal = Wal::open_truncated(
                &RealVfs,
                &dir,
                1,
                valid,
                Duration::ZERO,
                None,
            )
            .unwrap();
            wal.append(&b1).unwrap();
        }
        let (got, _) = replay(&RealVfs, &dir, 1, 3).unwrap();
        assert_eq!(got, vec![b0, b1]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_submissions_land_in_submit_order() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-group-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let batches: Vec<_> = (0..6).map(|i| batch(200 + i, 50 + i as u64)).collect();
        {
            let wal = Wal::create(&RealVfs, &dir, 2, Duration::ZERO, None).unwrap();
            // Submit everything first (buffered, un-synced), then wait
            // the tickets out of order: the file must still hold the
            // records in submit order, and one leader sync covers all.
            let tickets: Vec<_> =
                batches.iter().map(|b| wal.submit(b).unwrap()).collect();
            for t in tickets.into_iter().rev() {
                t.wait().unwrap();
            }
        }
        let (replayed, _) = replay(&RealVfs, &dir, 2, 3).unwrap();
        assert_eq!(replayed, batches, "WAL order == submit order");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sync_pending_drains_without_explicit_waits() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-drain-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let b0 = batch(128, 1);
        let b1 = batch(128, 2);
        let wal = Wal::create(&RealVfs, &dir, 3, Duration::ZERO, None).unwrap();
        let t0 = wal.submit(&b0).unwrap();
        let t1 = wal.submit(&b1).unwrap();
        wal.sync_pending().unwrap();
        // Both tickets are already durable: waits return immediately.
        t0.wait().unwrap();
        t1.wait().unwrap();
        let (replayed, _) = replay(&RealVfs, &dir, 3, 3).unwrap();
        assert_eq!(replayed, vec![b0, b1]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn batching_window_still_acks_every_append() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-window-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let wal =
            Wal::create(&RealVfs, &dir, 4, Duration::from_millis(2), None)
                .unwrap();
        let batches: Vec<_> = (0..3).map(|i| batch(64, 90 + i)).collect();
        for b in &batches {
            wal.append(b).unwrap();
        }
        let (replayed, _) = replay(&RealVfs, &dir, 4, 3).unwrap();
        assert_eq!(replayed, batches);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn transient_sync_failures_retry_then_ack() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-retry-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        // Ops: 0 = open_append, 1 = group write, 2 = first fsync
        // (injected transient failure), 3 = the retry (succeeds).
        let fv = FaultVfs::with_plan(
            9,
            vec![FaultSpec {
                at_op: 2,
                kind: FaultKind::SyncFail { transient: true },
            }],
        );
        let b = batch(128, 5);
        let wal = Wal::create(&*fv, &dir, 0, Duration::ZERO, None).unwrap();
        wal.append(&b).unwrap(); // retried fsync, no poison
        let b2 = batch(128, 6);
        wal.append(&b2).unwrap(); // generation still usable
        let (replayed, _) = replay(&RealVfs, &dir, 0, 3).unwrap();
        assert_eq!(replayed, vec![b, b2]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn exhausted_transient_retries_poison_the_generation() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-exhaust-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        // Four consecutive transient fsync failures > SYNC_RETRIES.
        let plan = (2..=5)
            .map(|op| FaultSpec {
                at_op: op,
                kind: FaultKind::SyncFail { transient: true },
            })
            .collect();
        let fv = FaultVfs::with_plan(10, plan);
        let wal = Wal::create(&*fv, &dir, 0, Duration::ZERO, None).unwrap();
        assert!(wal.append(&batch(128, 7)).is_err());
        // Poisoned: later submits refuse.
        let err = wal.submit(&batch(128, 8)).unwrap_err();
        assert!(err.to_string().contains("group-sync failure"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn hard_sync_failure_poisons_without_retry() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-hard-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let fv = FaultVfs::with_plan(
            11,
            vec![FaultSpec {
                at_op: 2,
                kind: FaultKind::SyncFail { transient: false },
            }],
        );
        let wal = Wal::create(&*fv, &dir, 0, Duration::ZERO, None).unwrap();
        assert!(wal.append(&batch(128, 9)).is_err());
        assert!(wal.submit(&batch(128, 10)).is_err());
        // The acked prefix (nothing) is what replay yields even though
        // the group's bytes may be fully in the file.
        let (replayed, _) = replay(&RealVfs, &dir, 0, 3).unwrap();
        assert!(replayed.len() <= 1, "at most the un-acked record");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn enospc_on_group_write_poisons_the_generation() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-enospc-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let fv = FaultVfs::with_plan(
            12,
            vec![FaultSpec { at_op: 1, kind: FaultKind::WriteNoSpace }],
        );
        let wal = Wal::create(&*fv, &dir, 0, Duration::ZERO, None).unwrap();
        let err = wal.append(&batch(128, 11)).unwrap_err();
        assert!(err.to_string().contains("ENOSPC"), "{err}");
        assert!(wal.submit(&batch(128, 12)).is_err());
        // Nothing was written: replay over the real file is empty.
        let (replayed, _) = replay(&RealVfs, &dir, 0, 3).unwrap();
        assert!(replayed.is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
