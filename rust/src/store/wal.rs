//! Write-ahead log: the durability of the memtable between segment
//! flushes.
//!
//! One file per WAL *generation* (`wal-<gen>.log`); each flush commits a
//! new generation through the manifest, so replay can never double-count
//! a batch that already lives in a segment — the crash window between
//! "manifest committed" and "old WAL deleted" leaves only an orphan file
//! that recovery ignores.
//!
//! Record framing (little-endian):
//!
//! ```text
//! u32 len | u32 crc32(payload) | payload[len]
//! payload = u32 row_count, then row_count x CodecBitmap::write_bytes
//! ```
//!
//! Replay walks records until the first short, checksum-invalid, or
//! structurally invalid record and returns the prefix — exactly the set
//! of appends whose fsync completed. Torn tails at *any* byte offset
//! therefore recover to a prefix-consistent memtable (property-tested in
//! `rust/tests/store_props.rs`).

use std::fs;
use std::io::{Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use super::Result;
use crate::bic::codec::{read_u32, CodecBitmap};
use crate::substrate::crc::crc32;

/// File name of WAL generation `gen`.
pub(crate) fn file_name(gen: u64) -> String {
    format!("wal-{gen:08}.log")
}

/// Path of WAL generation `gen` inside `dir`.
pub(crate) fn path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(file_name(gen))
}

/// An open, append-only WAL handle.
pub(crate) struct Wal {
    file: fs::File,
}

impl Wal {
    /// Create (or open for append) generation `gen`.
    pub(crate) fn create(dir: &Path, gen: u64) -> Result<Wal> {
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path(dir, gen))?;
        Ok(Wal { file })
    }

    /// Reopen generation `gen` truncated to its valid prefix (what
    /// replay measured), positioned for appending.
    pub(crate) fn open_truncated(
        dir: &Path,
        gen: u64,
        valid_len: u64,
    ) -> Result<Wal> {
        let mut file = fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path(dir, gen))?;
        file.set_len(valid_len)?;
        file.seek(SeekFrom::End(0))?;
        file.sync_all()?;
        Ok(Wal { file })
    }

    /// Append one batch record and fsync — returning `Ok` is the
    /// store's durability acknowledgement.
    pub(crate) fn append(&mut self, rows: &[CodecBitmap]) -> Result<()> {
        let body: usize =
            rows.iter().map(CodecBitmap::serialized_bytes).sum();
        let mut payload = Vec::with_capacity(4 + body);
        payload.extend_from_slice(&(rows.len() as u32).to_le_bytes());
        for r in rows {
            r.write_bytes(&mut payload);
        }
        let mut record = Vec::with_capacity(8 + payload.len());
        record.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        record.extend_from_slice(&crc32(&payload).to_le_bytes());
        record.extend_from_slice(&payload);
        self.file.write_all(&record)?;
        self.file.sync_data()?;
        Ok(())
    }
}

/// Replay generation `gen`: returns the durably-acknowledged batch
/// prefix and its byte length within the file. A missing file is an
/// empty log. Never errors on a torn/corrupt tail — that is the crash
/// case it exists for; only real I/O failures surface.
pub(crate) fn replay(
    dir: &Path,
    gen: u64,
    num_attrs: usize,
) -> Result<(Vec<Vec<CodecBitmap>>, u64)> {
    let buf = match fs::read(path(dir, gen)) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((Vec::new(), 0));
        }
        Err(e) => return Err(e.into()),
    };
    let mut batches = Vec::new();
    let mut pos = 0usize;
    loop {
        let record_start = pos;
        let Some(rest) = buf.get(pos..) else { break };
        if rest.len() < 8 {
            break; // torn header
        }
        let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
        let crc = u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]);
        let Some(payload) = rest.get(8..8 + len) else {
            break; // torn payload
        };
        if crc32(payload) != crc {
            break; // corrupt tail
        }
        let Some(rows) = decode_batch(payload, num_attrs) else {
            break; // structurally invalid (treated like corruption)
        };
        batches.push(rows);
        pos = record_start + 8 + len;
    }
    Ok((batches, pos as u64))
}

/// Decode one record payload; `None` on any structural violation.
fn decode_batch(payload: &[u8], num_attrs: usize) -> Option<Vec<CodecBitmap>> {
    let mut pos = 0usize;
    let m = read_u32(payload, &mut pos).ok()? as usize;
    if m != num_attrs {
        return None;
    }
    let mut rows = Vec::with_capacity(m);
    for _ in 0..m {
        rows.push(CodecBitmap::read_bytes(payload, &mut pos).ok()?);
    }
    if pos != payload.len() {
        return None;
    }
    let nbits = rows.first().map_or(0, CodecBitmap::len);
    if rows.iter().any(|r| r.len() != nbits) {
        return None;
    }
    Some(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bic::bitmap::Bitmap;
    use crate::substrate::rng::Xoshiro256;

    fn batch(n: usize, seed: u64) -> Vec<CodecBitmap> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..3)
            .map(|_| {
                let bools: Vec<bool> =
                    (0..n).map(|_| rng.chance(0.2)).collect();
                CodecBitmap::from_bitmap(&Bitmap::from_bools(&bools))
            })
            .collect()
    }

    #[test]
    fn append_replay_roundtrip_and_torn_tails() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let batches: Vec<_> = (0..4).map(|i| batch(500 + i, i as u64)).collect();
        {
            let mut wal = Wal::create(&dir, 5).unwrap();
            for b in &batches {
                wal.append(b).unwrap();
            }
        }
        let (replayed, len) = replay(&dir, 5, 3).unwrap();
        assert_eq!(replayed, batches);
        let full = fs::read(path(&dir, 5)).unwrap();
        assert_eq!(len, full.len() as u64);

        // Truncate at every byte: replay must yield exactly the whole
        // records that survive, in order.
        let mut boundaries = vec![0u64];
        {
            let mut p = 0usize;
            while p < full.len() {
                let l = u32::from_le_bytes([
                    full[p],
                    full[p + 1],
                    full[p + 2],
                    full[p + 3],
                ]) as usize;
                p += 8 + l;
                boundaries.push(p as u64);
            }
        }
        for cut in 0..=full.len() {
            fs::write(path(&dir, 5), &full[..cut]).unwrap();
            let (got, valid) = replay(&dir, 5, 3).unwrap();
            let expect_records =
                boundaries.iter().filter(|&&b| b <= cut as u64).count() - 1;
            assert_eq!(got.len(), expect_records, "cut at {cut}");
            assert_eq!(got, batches[..expect_records], "cut at {cut}");
            assert_eq!(valid, boundaries[expect_records], "cut at {cut}");
        }

        // Missing generation = empty log.
        let (none, len0) = replay(&dir, 99, 3).unwrap();
        assert!(none.is_empty());
        assert_eq!(len0, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_middle_record_cuts_the_prefix_there() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-corrupt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let batches: Vec<_> = (0..3).map(|i| batch(400, 10 + i)).collect();
        {
            let mut wal = Wal::create(&dir, 0).unwrap();
            for b in &batches {
                wal.append(b).unwrap();
            }
        }
        let mut bytes = fs::read(path(&dir, 0)).unwrap();
        // Flip one payload byte of the second record.
        let rec0_len =
            u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        let rec1_start = 8 + rec0_len;
        bytes[rec1_start + 8 + 5] ^= 0xFF;
        fs::write(path(&dir, 0), &bytes).unwrap();
        let (got, valid) = replay(&dir, 0, 3).unwrap();
        assert_eq!(got.len(), 1, "only the record before the corruption");
        assert_eq!(got[0], batches[0]);
        assert_eq!(valid as usize, rec1_start);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_truncated_resumes_appending() {
        let dir = std::env::temp_dir()
            .join(format!("bic-wal-resume-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let b0 = batch(300, 77);
        let b1 = batch(301, 78);
        {
            let mut wal = Wal::create(&dir, 1).unwrap();
            wal.append(&b0).unwrap();
        }
        // Simulate a torn tail, then recover + append.
        let mut bytes = fs::read(path(&dir, 1)).unwrap();
        let good_len = bytes.len();
        bytes.extend_from_slice(&[1, 2, 3]); // garbage tail
        fs::write(path(&dir, 1), &bytes).unwrap();
        let (got, valid) = replay(&dir, 1, 3).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(valid as usize, good_len);
        {
            let mut wal = Wal::open_truncated(&dir, 1, valid).unwrap();
            wal.append(&b1).unwrap();
        }
        let (got, _) = replay(&dir, 1, 3).unwrap();
        assert_eq!(got, vec![b0, b1]);
        let _ = fs::remove_dir_all(&dir);
    }
}
