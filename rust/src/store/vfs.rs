//! The store's virtual filesystem seam — every byte the durable store
//! reads or writes goes through a [`Vfs`], so the full failure taxonomy
//! (torn writes, fsync failures, rename failures, ENOSPC, read
//! bit-flips, crash points) can be injected **deterministically** in
//! tests instead of hoping the three scenarios we thought of are the
//! three that matter.
//!
//! - [`RealVfs`] is the zero-cost default: thin forwarding onto
//!   `std::fs`, the exact calls the store made before the seam existed.
//! - [`FaultVfs`] wraps the real filesystem and injects **seeded,
//!   reproducible** faults: every VFS call ticks a global operation
//!   counter, and a [`FaultSpec`] plan says what breaks at which op.
//!   Re-running with the same seed and plan replays the identical
//!   failure — which is what turns "a chaos test failed" into "a
//!   regression test exists".
//!
//! ## Fault classes ([`FaultKind`])
//!
//! | kind                    | applies to      | effect                                  |
//! |-------------------------|-----------------|-----------------------------------------|
//! | `Crash`                 | every op        | torn (seeded-prefix) write, then every later op fails — process death |
//! | `SyncFail{transient}`   | `sync`          | one fsync fails (`Interrupted` when transient, `Other` when hard) |
//! | `WriteNoSpace`          | `write_all`     | ENOSPC-style failure, nothing written    |
//! | `RenameFail`            | `rename`        | the rename never happens                 |
//! | `ReadFlip`              | `read`          | one seeded bit of the returned buffer flips (silent media corruption) |
//!
//! A kind that fires at an op it does not apply to is recorded in the
//! injection log and skipped — the op counter keeps ticking, so a crash
//! sweep over `0..ops` still visits every site.
//!
//! The typical crash-matrix workflow (see `rust/tests/store_props.rs`
//! and `store_smoke` phase 3): run the workload once over a
//! fault-free `FaultVfs` to *measure* its op count, then re-run it once
//! per crash point, recovering with [`RealVfs`] each time and asserting
//! acked-prefix durability.

use std::fmt;
use std::fs;
use std::io::{self, Seek, SeekFrom, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::substrate::rng::Xoshiro256;

/// An open file handle behind the VFS seam. The store only ever appends
/// and syncs through a handle; reads go through [`Vfs::read`] (whole
/// files — segments and WAL replay both validate full images).
pub trait VfsFile: Send {
    /// Append `buf` at the current position (end of file for the
    /// store's append-only handles).
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()>;
    /// Durably sync file data to the medium (fsync).
    fn sync(&mut self) -> io::Result<()>;
}

/// The filesystem operations the durable store performs, as one
/// injectable seam. `Send + Sync` so one instance serves the store, the
/// background compactor, and the scrubber concurrently.
pub trait Vfs: Send + Sync + fmt::Debug {
    /// Create `dir` and any missing parents.
    fn create_dir_all(&self, dir: &Path) -> io::Result<()>;
    /// Create (or truncate) a file for writing — the temp-file side of
    /// the write-fsync-rename protocol.
    fn create(&self, path: &Path) -> io::Result<Box<dyn VfsFile>>;
    /// Open a file for appending, creating it if missing (WAL handles).
    fn open_append(&self, path: &Path) -> io::Result<Box<dyn VfsFile>>;
    /// Open a file truncated to `len` bytes and positioned at its end —
    /// recovery resuming a WAL at its valid prefix.
    fn open_truncated(&self, path: &Path, len: u64)
        -> io::Result<Box<dyn VfsFile>>;
    /// Read a whole file.
    fn read(&self, path: &Path) -> io::Result<Vec<u8>>;
    /// Atomically rename `from` onto `to` (commit point of segment and
    /// manifest writes).
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Unlink a file.
    fn remove_file(&self, path: &Path) -> io::Result<()>;
    /// File names (not paths) directly inside `dir`.
    fn list(&self, dir: &Path) -> io::Result<Vec<String>>;
    /// Fsync the directory itself (makes renames durable). Callers
    /// treat failure as best-effort, matching platform support.
    fn sync_dir(&self, dir: &Path) -> io::Result<()>;
}

/// The production VFS: direct `std::fs` calls, no indirection cost
/// beyond one vtable hop per (already syscall-priced) operation.
#[derive(Debug, Default, Clone, Copy)]
pub struct RealVfs;

struct RealFile(fs::File);

impl VfsFile for RealFile {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        self.0.write_all(buf)
    }

    fn sync(&mut self) -> io::Result<()> {
        self.0.sync_data()
    }
}

impl Vfs for RealVfs {
    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        fs::create_dir_all(dir)
    }

    fn create(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        Ok(Box::new(RealFile(fs::File::create(path)?)))
    }

    fn open_append(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        let f = fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Box::new(RealFile(f)))
    }

    fn open_truncated(
        &self,
        path: &Path,
        len: u64,
    ) -> io::Result<Box<dyn VfsFile>> {
        let mut f = fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        f.set_len(len)?;
        f.seek(SeekFrom::End(0))?;
        f.sync_all()?;
        Ok(Box::new(RealFile(f)))
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        fs::read(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        fs::remove_file(path)
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in fs::read_dir(dir)? {
            if let Some(name) = entry?.file_name().to_str() {
                out.push(name.to_string());
            }
        }
        Ok(out)
    }

    fn sync_dir(&self, dir: &Path) -> io::Result<()> {
        fs::File::open(dir)?.sync_all()
    }
}

/// One injectable fault class. See the module table for which
/// operations each applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Process death at this op: a write lands as a seeded torn prefix,
    /// any other op never happens, and every subsequent VFS call fails.
    Crash,
    /// The fsync at this op fails. `transient: true` reports
    /// [`io::ErrorKind::Interrupted`] (the WAL retries those with
    /// backoff); `false` reports a hard error (poisons the generation).
    SyncFail {
        /// Whether the failure is of a retryable class.
        transient: bool,
    },
    /// The write at this op fails with [`io::ErrorKind::StorageFull`]
    /// and writes nothing (disk-full).
    WriteNoSpace,
    /// The rename at this op fails and does not happen.
    RenameFail,
    /// The read at this op returns its bytes with one seeded bit
    /// flipped — silent media corruption for the CRCs to catch.
    ReadFlip,
}

/// A planned fault: `kind` fires when the global op counter reaches
/// `at_op` (ops are numbered from 0 in call order).
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// The operation index the fault fires at.
    pub at_op: u64,
    /// What breaks there.
    pub kind: FaultKind,
}

struct FaultState {
    rng: Xoshiro256,
    next_op: u64,
    plan: Vec<FaultSpec>,
    crashed: bool,
    injected: Vec<String>,
}

/// A deterministic fault-injecting VFS over the real filesystem.
/// Construction fixes a seed and a fault plan; identical (seed, plan,
/// workload) triples replay identical failures. Also usable with an
/// empty plan purely to *count* the operations a workload performs —
/// the measurement half of a crash-point sweep.
pub struct FaultVfs {
    inner: RealVfs,
    state: Arc<Mutex<FaultState>>,
}

impl fmt::Debug for FaultVfs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        f.debug_struct("FaultVfs")
            .field("ops", &st.next_op)
            .field("plan", &st.plan)
            .field("crashed", &st.crashed)
            .finish()
    }
}

impl FaultVfs {
    /// A fault-free instance that only counts operations — run the
    /// workload over it once to learn how many injection points exist.
    pub fn counting(seed: u64) -> Arc<FaultVfs> {
        Self::with_plan(seed, Vec::new())
    }

    /// Crash (torn write + total failure afterwards) at operation `op`.
    pub fn crash_at(seed: u64, op: u64) -> Arc<FaultVfs> {
        Self::with_plan(seed, vec![FaultSpec { at_op: op, kind: FaultKind::Crash }])
    }

    /// An instance executing an explicit fault plan.
    pub fn with_plan(seed: u64, plan: Vec<FaultSpec>) -> Arc<FaultVfs> {
        Arc::new(FaultVfs {
            inner: RealVfs,
            state: Arc::new(Mutex::new(FaultState {
                rng: Xoshiro256::seeded(seed),
                next_op: 0,
                plan,
                crashed: false,
                injected: Vec::new(),
            })),
        })
    }

    /// Operations performed so far (the next op index).
    pub fn ops(&self) -> u64 {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).next_op
    }

    /// Human-readable log of every fault actually injected (and every
    /// planned fault skipped for applying to an incompatible op).
    pub fn injected(&self) -> Vec<String> {
        self.state
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .injected
            .clone()
    }
}

fn crashed_err() -> io::Error {
    io::Error::other("injected crash: vfs is dead until recovery")
}

impl FaultState {
    /// Advance the op counter and return the fault (if any) firing at
    /// this op. `None` after a crash means "already dead" is handled by
    /// the caller via `crashed`.
    fn tick(&mut self, what: &str, path: &Path) -> Option<FaultKind> {
        let op = self.next_op;
        self.next_op += 1;
        let kind = self
            .plan
            .iter()
            .find(|s| s.at_op == op)
            .map(|s| s.kind)?;
        self.injected
            .push(format!("op {op}: {kind:?} at {what} {}", path.display()));
        Some(kind)
    }
}

/// Applies `kind` when it matches the op class; returns the error to
/// inject, `None` to proceed normally (mismatched kind, logged already).
macro_rules! fault_gate {
    ($state:expr, $what:expr, $path:expr, { $($kind:pat => $effect:expr),+ $(,)? }) => {{
        let mut st = $state.lock().unwrap_or_else(|e| e.into_inner());
        if st.crashed {
            return Err(crashed_err());
        }
        match st.tick($what, $path) {
            None => None,
            $(Some($kind) => $effect(&mut st),)+
            Some(_) => None, // kind does not apply to this op class
        }
    }};
}

fn crash(st: &mut FaultState) -> Option<io::Error> {
    st.crashed = true;
    Some(crashed_err())
}

struct FaultFile {
    inner: Box<dyn VfsFile>,
    path: std::path::PathBuf,
    state: Arc<Mutex<FaultState>>,
}

impl VfsFile for FaultFile {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        let torn: Option<usize> = {
            let fault = fault_gate!(self.state, "write", &self.path, {
                FaultKind::Crash => |st: &mut FaultState| {
                    // Torn write: a seeded prefix reaches the file, the
                    // rest (and the ack) never does.
                    let cut = st.rng.next_below(buf.len() as u64 + 1) as usize;
                    st.crashed = true;
                    Some(cut)
                },
                FaultKind::WriteNoSpace => |_: &mut FaultState| {
                    Some(usize::MAX) // marker: fail without writing
                },
            });
            match fault {
                None => None,
                Some(cut) => Some(cut),
            }
        };
        match torn {
            None => self.inner.write_all(buf),
            Some(usize::MAX) => Err(io::Error::new(
                io::ErrorKind::StorageFull,
                "injected ENOSPC",
            )),
            Some(cut) => {
                let _ = self.inner.write_all(&buf[..cut]);
                let _ = self.inner.sync();
                Err(crashed_err())
            }
        }
    }

    fn sync(&mut self) -> io::Result<()> {
        let fault = fault_gate!(self.state, "sync", &self.path, {
            FaultKind::Crash => crash,
            FaultKind::SyncFail { transient } => move |_: &mut FaultState| {
                Some(if transient {
                    io::Error::new(
                        io::ErrorKind::Interrupted,
                        "injected transient fsync failure",
                    )
                } else {
                    io::Error::other("injected hard fsync failure")
                })
            },
        });
        match fault {
            Some(e) => Err(e),
            None => self.inner.sync(),
        }
    }
}

impl FaultVfs {
    fn wrap(
        &self,
        path: &Path,
        inner: Box<dyn VfsFile>,
    ) -> Box<dyn VfsFile> {
        Box::new(FaultFile {
            inner,
            path: path.to_path_buf(),
            state: Arc::clone(&self.state),
        })
    }

    /// Trait-object-friendly gate for whole-VFS ops (open/rename/...).
    fn gate(&self, what: &str, path: &Path) -> io::Result<()> {
        let fault = fault_gate!(self.state, what, path, {
            FaultKind::Crash => crash,
            FaultKind::RenameFail => |_: &mut FaultState| {
                (what == "rename")
                    .then(|| io::Error::other("injected rename failure"))
            },
        });
        match fault {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Vfs for FaultVfs {
    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        self.gate("create_dir_all", dir)?;
        self.inner.create_dir_all(dir)
    }

    fn create(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        self.gate("create", path)?;
        Ok(self.wrap(path, self.inner.create(path)?))
    }

    fn open_append(&self, path: &Path) -> io::Result<Box<dyn VfsFile>> {
        self.gate("open_append", path)?;
        Ok(self.wrap(path, self.inner.open_append(path)?))
    }

    fn open_truncated(
        &self,
        path: &Path,
        len: u64,
    ) -> io::Result<Box<dyn VfsFile>> {
        self.gate("open_truncated", path)?;
        Ok(self.wrap(path, self.inner.open_truncated(path, len)?))
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        let flip: Option<io::Error> = {
            let fault = fault_gate!(self.state, "read", path, {
                FaultKind::Crash => crash,
                FaultKind::ReadFlip => |_: &mut FaultState| None,
            });
            fault
        };
        if let Some(e) = flip {
            return Err(e);
        }
        let mut buf = self.inner.read(path)?;
        // A ReadFlip planned for the op we just ticked: find it in the
        // log (last entry names this op) and apply the seeded bit flip.
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let flipped = st
            .injected
            .last()
            .is_some_and(|l| l.contains("ReadFlip") && l.contains("read"));
        if flipped && !buf.is_empty() {
            let bit = st.rng.next_below(buf.len() as u64 * 8);
            buf[(bit / 8) as usize] ^= 1 << (bit % 8);
        }
        Ok(buf)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.gate("rename", from)?;
        self.inner.rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        self.gate("remove_file", path)?;
        self.inner.remove_file(path)
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<String>> {
        self.gate("list", dir)?;
        self.inner.list(dir)
    }

    fn sync_dir(&self, dir: &Path) -> io::Result<()> {
        self.gate("sync_dir", dir)?;
        self.inner.sync_dir(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("bic-vfs-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn real_vfs_roundtrips_files() {
        let d = tmp("real");
        let vfs = RealVfs;
        let p = d.join("a.tmp");
        {
            let mut f = vfs.create(&p).unwrap();
            f.write_all(b"hello ").unwrap();
            f.write_all(b"world").unwrap();
            f.sync().unwrap();
        }
        vfs.rename(&p, &d.join("a")).unwrap();
        assert_eq!(vfs.read(&d.join("a")).unwrap(), b"hello world");
        let names = vfs.list(&d).unwrap();
        assert_eq!(names, vec!["a".to_string()]);
        vfs.remove_file(&d.join("a")).unwrap();
        assert!(vfs.read(&d.join("a")).is_err());
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn crash_tears_the_write_and_kills_every_later_op() {
        let d = tmp("crash");
        // op 0 = create, op 1 = the write (crash here), later ops dead.
        let vfs = FaultVfs::with_plan(
            7,
            vec![FaultSpec { at_op: 1, kind: FaultKind::Crash }],
        );
        let p = d.join("x.tmp");
        let mut f = vfs.create(&p).unwrap();
        let err = f.write_all(&[0xAA; 64]).unwrap_err();
        assert!(err.to_string().contains("injected crash"), "{err}");
        // The torn prefix is on disk and strictly shorter than the buf.
        let on_disk = fs::read(&p).unwrap();
        assert!(on_disk.len() <= 64, "torn prefix, got {}", on_disk.len());
        // Everything after the crash fails, files and vfs ops alike.
        assert!(f.sync().is_err());
        assert!(vfs.read(&p).is_err());
        assert!(vfs.rename(&p, &d.join("y")).is_err());
        assert!(vfs.injected().iter().any(|l| l.contains("Crash")));
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn crash_sweep_is_deterministic_per_seed() {
        // The same (seed, op) pair must tear the same number of bytes.
        let lens: Vec<usize> = (0..2)
            .map(|_| {
                let d = tmp("det");
                let vfs = FaultVfs::crash_at(42, 1);
                let mut f = vfs.create(&d.join("x")).unwrap();
                let _ = f.write_all(&[1u8; 256]);
                let n = fs::read(d.join("x")).unwrap().len();
                let _ = fs::remove_dir_all(&d);
                n
            })
            .collect();
        assert_eq!(lens[0], lens[1], "same seed, same torn length");
    }

    #[test]
    fn sync_and_rename_and_enospc_faults_fire_once() {
        let d = tmp("faults");
        let vfs = FaultVfs::with_plan(
            3,
            vec![
                FaultSpec {
                    at_op: 2,
                    kind: FaultKind::SyncFail { transient: true },
                },
                FaultSpec { at_op: 4, kind: FaultKind::WriteNoSpace },
                FaultSpec { at_op: 6, kind: FaultKind::RenameFail },
            ],
        );
        let p = d.join("f.tmp");
        let mut f = vfs.create(&p).unwrap(); // op 0
        f.write_all(b"abc").unwrap(); // op 1
        let e = f.sync().unwrap_err(); // op 2: transient
        assert_eq!(e.kind(), io::ErrorKind::Interrupted);
        f.sync().unwrap(); // op 3: retry succeeds
        let e = f.write_all(b"def").unwrap_err(); // op 4: ENOSPC
        assert_eq!(e.kind(), io::ErrorKind::StorageFull);
        f.write_all(b"def").unwrap(); // op 5
        let e = vfs.rename(&p, &d.join("f")).unwrap_err(); // op 6
        assert!(e.to_string().contains("rename"), "{e}");
        vfs.rename(&p, &d.join("f")).unwrap(); // op 7
        assert_eq!(fs::read(d.join("f")).unwrap(), b"abcdef");
        assert_eq!(vfs.ops(), 8);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn read_flip_corrupts_exactly_one_bit() {
        let d = tmp("flip");
        let vfs = FaultVfs::with_plan(
            11,
            vec![FaultSpec { at_op: 3, kind: FaultKind::ReadFlip }],
        );
        let p = d.join("blob");
        let payload = vec![0u8; 128];
        let mut f = vfs.create(&p).unwrap(); // op 0
        f.write_all(&payload).unwrap(); // op 1
        assert_eq!(vfs.read(&p).unwrap(), payload); // op 2: clean
        let flipped = vfs.read(&p).unwrap(); // op 3: one bit flips
        let diff: u32 = flipped
            .iter()
            .zip(&payload)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(diff, 1, "exactly one flipped bit");
        assert_eq!(vfs.read(&p).unwrap(), payload); // op 4: clean again
        let _ = fs::remove_dir_all(&d);
    }
}
