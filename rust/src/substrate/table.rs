//! Aligned-text table and CSV emitters for experiment reports — every
//! `experiments::*` harness prints the paper's tables/figure series
//! through these.

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render with a header underline; first column left-aligned, the
    /// rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let w = self.widths();
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    if i == 0 {
                        format!("{:<width$}", c, width = w[i])
                    } else {
                        format!("{:>width$}", c, width = w[i])
                    }
                })
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (quotes cells containing separators).
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["x", "1"]).row(vec!["longer", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].chars().all(|c| c == '-'));
        // Right-aligned numeric column.
        assert!(lines[2].ends_with(" 1"));
        assert!(lines[3].ends_with("22"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn ragged_row_panics() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["a,b", "say \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }
}
