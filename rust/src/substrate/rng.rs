//! Deterministic PRNGs for workload generation, simulation, and property
//! tests.
//!
//! `SplitMix64` is used for seeding; `Xoshiro256` (xoshiro256**) is the
//! general-purpose generator. Both are tiny, fast, and reproducible across
//! platforms — exactly what the workload generators and the property-test
//! harness need. (The vendored crate shelf has `rand_core` but no PRNG
//! implementation, so these are written out here.)

/// SplitMix64 — used to expand a single `u64` seed into a stream of
/// well-mixed words (notably to seed [`Xoshiro256`]).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that any `u64` (including 0) gives a good state.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // Widening multiply; rejection keeps it exactly uniform.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)` (usize convenience for index generation).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// One sample from Zipf(s) over ranks `1..=n`, via inverse-CDF on a
    /// precomputed table-free rejection (n small enough for our workloads
    /// that a simple linear pass on a cached normalizer is fine).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        // Inverse-transform with on-the-fly harmonic accumulation.
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let u = self.next_f64() * h;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            if acc >= u {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential variate with the given rate (inter-arrival times).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across constructions.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256::seeded(42);
        let mut r2 = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::seeded(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3, "different seeds should diverge");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::seeded(99);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let mut r = Xoshiro256::seeded(5);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 3, "rank 0 should dominate: {counts:?}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256::seeded(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Xoshiro256::seeded(13);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
