//! Minimal JSON writer + reader (no `serde` on the offline shelf): enough
//! to dump machine-readable experiment results next to the human-readable
//! tables, and to read them back — the bench-regression gate
//! (`src/bin/bench_gate.rs`) parses the `BENCH_*.json` artifacts this
//! module wrote.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (build with the `From` impls and [`Json::obj`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap for deterministic key order.
    Obj(BTreeMap<String, Json>),
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl Json {
    /// Object builder: `Json::obj([("k", 1.0.into()), ...])`.
    pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Insert into an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on a non-object"),
        }
    }

    /// Parse a JSON document. Strict enough for the repo's own artifacts
    /// (no comments, no trailing commas); numbers parse as `f64`, like
    /// the writer renders them.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Boolean accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object-member accessor (`None` on non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Recursive-descent reader over the raw bytes.
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected value at byte {}", self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| {
                                    format!("bad \\u escape at byte {}", self.i)
                                })?;
                            self.i += 4;
                            // Surrogates never appear in our own artifacts;
                            // map them to the replacement character.
                            out.push(char::from_u32(hex).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte sequences pass
                    // through verbatim).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut j = Json::obj([
            ("name", "fig7".into()),
            ("points", vec![1.0, 2.5].into()),
        ]);
        j.set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig7","ok":true,"points":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    #[should_panic(expected = "non-object")]
    fn set_on_array_panics() {
        Json::Arr(vec![]).set("k", 1.0);
    }

    #[test]
    fn parse_roundtrips_render() {
        let mut j = Json::obj([
            ("name", "bitmap/and-1Mbit".into()),
            ("mean_s", (1.25e-6).into()),
            ("bytes_per_iter", Json::Null),
            ("ok", true.into()),
            ("tags", vec!["a", "b\"c\\d"].into()),
        ]);
        j.set("nested", Json::obj([("k", (-3.5).into())]));
        let parsed = Json::parse(&j.render()).expect("parse");
        assert_eq!(parsed, j);
        // And the re-render is byte-identical (deterministic key order).
        assert_eq!(parsed.render(), j.render());
    }

    #[test]
    fn parse_accepts_whitespace_and_scalars() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , -3e2 , true , false , null ] }\n")
            .unwrap();
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 6);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].as_f64(), Some(-300.0));
        assert_eq!(arr[3].as_bool(), Some(true));
        assert_eq!(arr[5], Json::Null);
        assert_eq!(Json::parse("\"x\\u0041y\"").unwrap().as_str(), Some("xAy"));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "{\"a\" 1}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessors_are_type_safe() {
        let j = Json::parse("{\"n\":4,\"s\":\"v\"}").unwrap();
        assert_eq!(j.get("n").and_then(Json::as_f64), Some(4.0));
        assert_eq!(j.get("n").and_then(Json::as_str), None);
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("n"), None);
    }
}
