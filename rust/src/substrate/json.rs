//! Minimal JSON writer (no `serde` on the offline shelf): enough to dump
//! machine-readable experiment results next to the human-readable tables.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (build with the `From` impls and [`Json::obj`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap for deterministic key order.
    Obj(BTreeMap<String, Json>),
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl Json {
    /// Object builder: `Json::obj([("k", 1.0.into()), ...])`.
    pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Insert into an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on a non-object"),
        }
    }

    /// Serialize (compact).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut j = Json::obj([
            ("name", "fig7".into()),
            ("points", vec![1.0, 2.5].into()),
        ]);
        j.set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig7","ok":true,"points":[1,2.5]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    #[should_panic(expected = "non-object")]
    fn set_on_array_panics() {
        Json::Arr(vec![]).set("k", 1.0);
    }
}
