//! Tiny CLI argument parser (no `clap` on the offline shelf):
//! `prog <subcommand> [--flag] [--key value|--key=value] [positional...]`.

use std::collections::HashMap;

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    positionals: Vec<String>,
}

/// A flag without a value stores this marker.
const PRESENT: &str = "\u{1}";

impl Args {
    /// Parse raw arguments (without argv[0]). The first non-flag token is
    /// the subcommand; `--key value` and `--key=value` both work; a flag
    /// followed by another flag (or nothing) is boolean.
    pub fn parse(raw: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), PRESENT.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Boolean flag presence.
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// String flag value (None if absent or boolean-style).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str).filter(|v| *v != PRESENT)
    }

    /// Typed flag with default; errors on unparseable values.
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("--{name}: cannot parse {v:?}")),
        }
    }

    /// Required string flag.
    pub fn require(&self, name: &str) -> Result<&str, String> {
        self.get(name).ok_or_else(|| format!("missing required --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["run", "input.dat", "more"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positionals(), ["input.dat", "more"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["x", "--cores", "4", "--vdd=0.9"]);
        assert_eq!(a.get("cores"), Some("4"));
        assert_eq!(a.get("vdd"), Some("0.9"));
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["x", "--verbose", "--json"]);
        assert!(a.has("verbose") && a.has("json"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn flag_before_another_flag_is_boolean() {
        let a = parse(&["x", "--quiet", "--cores", "2"]);
        assert!(a.has("quiet"));
        assert_eq!(a.get("quiet"), None);
        assert_eq!(a.get("cores"), Some("2"));
    }

    #[test]
    fn typed_parsing_with_default() {
        let a = parse(&["x", "--n", "17"]);
        assert_eq!(a.get_parsed("n", 3usize).unwrap(), 17);
        assert_eq!(a.get_parsed("missing", 3usize).unwrap(), 3);
        assert!(a.get_parsed::<usize>("n", 0).is_ok());
        let bad = parse(&["x", "--n", "abc"]);
        assert!(bad.get_parsed::<usize>("n", 0).is_err());
    }

    #[test]
    fn require_errors_when_absent() {
        let a = parse(&["x"]);
        assert!(a.require("out").is_err());
    }
}
