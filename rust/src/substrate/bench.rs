//! Criterion-like micro-benchmark harness (the vendored crate shelf has
//! no `criterion`, so the repo ships its own): adaptive iteration counts,
//! warmup, sample statistics, and aligned reporting. Used by every target
//! under `rust/benches/`.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{format_si, Summary};

/// True when `BENCH_SMOKE` selects the short CI measurement budget (the
/// `bench-smoke` job via `ci.sh --bench`).
pub fn smoke_mode() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// The bench-smoke measurement budget: same cases and names, ~10x less
/// wall time per case.
pub fn smoke_config() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(20),
        samples: 5,
        min_sample_time: Duration::from_millis(2),
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup budget before sampling.
    pub warmup: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Minimum wall time per sample (iterations adapt to reach it).
    pub min_sample_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            samples: 20,
            min_sample_time: Duration::from_millis(10),
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time [s].
    pub per_iter: Summary,
    /// Iterations per sample used.
    pub iters_per_sample: u64,
    /// Optional throughput denominator: bytes processed per iteration.
    pub bytes_per_iter: Option<u64>,
    /// Optional bench-specific structured payload (e.g. latency
    /// quantiles), carried verbatim into the `BENCH_*.json` case under
    /// `"extra"`. The regression gate ignores it.
    pub extra: Option<Json>,
}

impl BenchResult {
    /// Mean throughput [bytes/s] if a byte count was attached.
    pub fn throughput(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.per_iter.mean)
    }

    /// The `BENCH_*.json` case shape (`name`, `mean_s`, ...) that the
    /// `bench_gate` regression comparator parses — one definition so the
    /// emitting benches and the gate cannot drift apart.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj([
            ("name", self.name.as_str().into()),
            ("mean_s", self.per_iter.mean.into()),
            ("stddev_s", self.per_iter.stddev.into()),
            ("samples", self.per_iter.n.into()),
            ("iters_per_sample", self.iters_per_sample.into()),
        ]);
        match self.bytes_per_iter {
            Some(b) => j.set("bytes_per_iter", b),
            None => j.set("bytes_per_iter", Json::Null),
        }
        match self.throughput() {
            Some(tp) => j.set("throughput_bps", tp),
            None => j.set("throughput_bps", Json::Null),
        }
        if let Some(extra) = &self.extra {
            j.set("extra", extra.clone());
        }
        j
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        let mut s = format!(
            "{:<44} {:>14}/iter  (±{:>5.1}%, n={})",
            self.name,
            format_si(self.per_iter.mean, "s"),
            self.per_iter.rsd() * 100.0,
            self.per_iter.n,
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:>12}", format_si(tp, "B/s")));
        }
        s
    }
}

/// A named benchmark run.
pub struct Bench {
    cfg: BenchConfig,
    name: String,
    bytes: Option<u64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self { cfg: BenchConfig::default(), name: name.into(), bytes: None }
    }

    /// [`Bench::new`] under the environment-selected mode: the smoke
    /// budget when [`smoke_mode`] is on, the default otherwise.
    pub fn auto(name: impl Into<String>) -> Self {
        let b = Self::new(name);
        if smoke_mode() {
            b.with_config(smoke_config())
        } else {
            b
        }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Attach a throughput denominator (bytes processed per iteration).
    pub fn bytes(mut self, bytes: u64) -> Self {
        self.bytes = Some(bytes);
        self
    }

    /// Run the closure under the harness. `f` should return something
    /// observable to keep the optimizer honest; the return value is
    /// passed through `std::hint::black_box`.
    pub fn run<T>(self, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + iteration calibration.
        let warm_start = Instant::now();
        let mut calib_iters: u64 = 0;
        while warm_start.elapsed() < self.cfg.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter_est = self.cfg.warmup.as_secs_f64() / calib_iters as f64;
        let iters = ((self.cfg.min_sample_time.as_secs_f64() / per_iter_est)
            .ceil() as u64)
            .max(1);

        let mut samples = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let result = BenchResult {
            name: self.name,
            per_iter: Summary::of(&samples),
            iters_per_sample: iters,
            bytes_per_iter: self.bytes,
            extra: None,
        };
        println!("{}", result.line());
        result
    }
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            samples: 5,
            min_sample_time: Duration::from_micros(200),
        }
    }

    #[test]
    fn measures_something_positive() {
        let r = Bench::new("noop-ish")
            .with_config(fast_cfg())
            .run(|| (0..100u64).sum::<u64>());
        assert!(r.per_iter.mean > 0.0);
        assert_eq!(r.per_iter.n, 5);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn throughput_uses_bytes() {
        let r = Bench::new("tp")
            .with_config(fast_cfg())
            .bytes(1_000)
            .run(|| std::hint::black_box(42));
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0);
    }

    #[test]
    fn to_json_has_the_gate_fields() {
        let r = BenchResult {
            name: "x".into(),
            per_iter: Summary::of(&[1e-6, 1e-6]),
            iters_per_sample: 10,
            bytes_per_iter: None,
            extra: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("x"));
        assert!(j.get("mean_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(j.get("bytes_per_iter"), Some(&Json::Null));
        assert_eq!(j.get("extra"), None, "no extra field unless attached");
        let mut r2 = r;
        r2.extra = Some(Json::obj([("p50_ns", 120u64.into())]));
        let j2 = r2.to_json();
        assert_eq!(
            j2.get("extra")
                .and_then(|e| e.get("p50_ns"))
                .and_then(Json::as_f64),
            Some(120.0)
        );
    }

    #[test]
    fn line_formats() {
        let r = BenchResult {
            name: "x".into(),
            per_iter: Summary::of(&[1e-6, 1e-6]),
            iters_per_sample: 10,
            bytes_per_iter: Some(512),
            extra: None,
        };
        let line = r.line();
        assert!(line.contains("/iter"));
        assert!(line.contains("B/s"));
    }
}
