//! Statistics helpers for the bench harness and experiment reports.

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        };
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.stddev / self.mean }
    }
}

/// Percentile (0..=1) by nearest-rank on a copy.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "percentile out of range");
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

/// Ordinary least squares `y = a + b*x`; returns (a, b).
pub fn linear_fit(points: &[(f64, f64)]) -> (f64, f64) {
    assert!(points.len() >= 2, "need at least two points");
    let n = points.len() as f64;
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    assert!(denom.abs() > 1e-30, "degenerate x values");
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Format a value with an SI prefix: `format_si(2.64e-9, "W")` = "2.64 nW".
pub fn format_si(value: f64, unit: &str) -> String {
    const PREFIXES: [(f64, &str); 9] = [
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
        (1.0, ""),
        (1e-3, "m"),
        (1e-6, "u"),
        (1e-9, "n"),
        (1e-12, "p"),
    ];
    if value == 0.0 {
        return format!("0 {unit}");
    }
    let mag = value.abs();
    for &(scale, prefix) in &PREFIXES {
        if mag >= scale {
            return format!("{:.3} {}{}", value / scale, prefix, unit);
        }
    }
    format!("{:.3e} {}", value, unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 2.0_f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn even_median() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_zero() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 99.0);
        assert!((percentile(&xs, 0.5) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let pts: Vec<(f64, f64)> =
            (0..10).map(|i| (i as f64, 3.0 + 2.0 * i as f64)).collect();
        let (a, b) = linear_fit(&pts);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(format_si(2.64e-9, "W"), "2.640 nW");
        assert_eq!(format_si(162.9e-12, "J"), "162.900 pJ");
        assert_eq!(format_si(41e6, "Hz"), "41.000 MHz");
        assert_eq!(format_si(0.0, "W"), "0 W");
        assert_eq!(format_si(-6.68e-3, "W"), "-6.680 mW");
    }
}
