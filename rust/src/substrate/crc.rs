//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — the checksum
//! the durable store stamps on WAL records and segment files. Vendored
//! like the rest of the substrate (no `crc32fast` on the offline shelf);
//! a 256-entry table built at compile time keeps the per-byte loop to one
//! shift + one xor.

/// Compile-time CRC-32 lookup table (one entry per byte value).
const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut n = 0usize;
    while n < 256 {
        let mut crc = n as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            k += 1;
        }
        table[n] = crc;
        n += 1;
    }
    table
};

/// CRC-32 of `bytes` (init 0xFFFFFFFF, final xor 0xFFFFFFFF — the common
/// zlib/PNG/Ethernet convention).
pub fn crc32(bytes: &[u8]) -> u32 {
    update(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

/// Streaming form: feed chunks through `update` starting from
/// `0xFFFFFFFF`, xor with `0xFFFFFFFF` at the end.
pub fn update(state: u32, bytes: &[u8]) -> u32 {
    let mut crc = state;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The classic check value for this CRC variant.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        for split in 0..data.len() {
            let s = update(0xFFFF_FFFF, &data[..split]);
            let s = update(s, &data[split..]);
            assert_eq!(s ^ 0xFFFF_FFFF, crc32(data), "split {split}");
        }
    }

    #[test]
    fn detects_single_bit_flips() {
        let data = b"segment payload bytes";
        let base = crc32(data);
        let mut copy = *data;
        for i in 0..copy.len() {
            for bit in 0..8 {
                copy[i] ^= 1 << bit;
                assert_ne!(crc32(&copy), base, "flip byte {i} bit {bit}");
                copy[i] ^= 1 << bit;
            }
        }
    }
}
