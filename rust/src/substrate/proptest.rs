//! Mini property-testing harness (no `proptest` on the offline crate
//! shelf). Deterministic: every case derives from a base seed, and a
//! failure report prints the exact seed + case index so the case can be
//! replayed with `Gen::replay`.
//!
//! No generic shrinking — generators are encouraged to bias toward small
//! sizes instead (all the `Gen` size helpers do).

use super::rng::Xoshiro256;

/// Per-case value generator.
pub struct Gen {
    rng: Xoshiro256,
    /// Identifies this case for replay.
    pub seed: u64,
    pub case: u64,
}

impl Gen {
    fn for_case(seed: u64, case: u64) -> Self {
        // Decorrelate cases: hash (seed, case) through the seeder.
        Self {
            rng: Xoshiro256::seeded(seed ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            seed,
            case,
        }
    }

    /// Rebuild the generator of a reported failure.
    pub fn replay(seed: u64, case: u64) -> Self {
        Self::for_case(seed, case)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        self.rng.range(lo, hi_inclusive + 1)
    }

    /// Size generator biased toward small values (geometric-ish): small
    /// cases dominate, occasionally large ones appear.
    pub fn size(&mut self, max: usize) -> usize {
        let r = self.rng.next_f64();
        ((r * r * max as f64) as usize).min(max)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// An alphabet word (the chip's 8-bit record/key domain).
    pub fn word(&mut self) -> i32 {
        self.rng.range(0, 256) as i32
    }

    /// Access to the raw RNG for anything else.
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Run `cases` randomized cases of a property; panics with a replayable
/// report on the first failure. The property returns `Err(message)` (or
/// panics) to signal failure.
pub fn check(
    name: &str,
    seed: u64,
    cases: u64,
    mut prop: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut g = Gen::for_case(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at seed={seed} case={case}: {msg}\n\
                 replay with Gen::replay({seed}, {case})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0u64;
        check("tautology", 1, 50, |_| {
            ran += 1;
            Ok(())
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "seed=7 case=")]
    fn failure_reports_seed_and_case() {
        check("always-fails-eventually", 7, 100, |g| {
            if g.case >= 3 { Err("boom".into()) } else { Ok(()) }
        });
    }

    #[test]
    fn replay_reproduces_values() {
        let mut recorded = Vec::new();
        check("record", 11, 5, |g| {
            recorded.push((g.case, g.u64()));
            Ok(())
        });
        for &(case, value) in &recorded {
            let mut g = Gen::replay(11, case);
            assert_eq!(g.u64(), value, "case {case} must replay identically");
        }
    }

    #[test]
    fn size_is_biased_small_but_reaches_max() {
        let mut g = Gen::for_case(3, 0);
        let sizes: Vec<usize> = (0..2000).map(|_| g.size(100)).collect();
        let small = sizes.iter().filter(|&&s| s < 25).count();
        assert!(small > 800, "small sizes should dominate: {small}");
        assert!(*sizes.iter().max().unwrap() > 80, "large sizes must appear");
    }

    #[test]
    fn word_is_in_alphabet() {
        let mut g = Gen::for_case(5, 0);
        for _ in 0..1000 {
            let w = g.word();
            assert!((0..256).contains(&w));
        }
    }
}
