//! Hand-rolled infrastructure substrates.
//!
//! The build is fully offline against a small vendored crate shelf (no
//! `clap`/`criterion`/`proptest`/`serde`/`rand`), so the framework pieces a
//! production repo would pull from crates.io are implemented here instead:
//! a PRNG, a property-testing harness, a benchmarking harness, statistics,
//! a CLI argument parser, and table/CSV/JSON emitters.

pub mod bench;
pub mod cli;
pub mod crc;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
