//! `sotb_bic` — reproduction of the 65-nm SOTB bitmap-index-creation (BIC)
//! core and its multi-core, energy-proportional runtime.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod baselines;
pub mod bic;
pub mod bsi;
pub mod cli_app;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod obs;
pub mod power;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod store;
pub mod substrate;

pub use cli_app::cli_main;
pub use engine::{AggFn, AggResult, Engine, EngineBuilder, PallasError};
