//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The build image for this repository has no network access and no
//! prebuilt `xla_extension` shared library, so the real bindings cannot be
//! compiled here. This crate mirrors the exact API surface the runtime
//! layer uses (`PjRtClient`, `PjRtLoadedExecutable`, `Literal`,
//! `HloModuleProto`, `XlaComputation`) and fails *at runtime* with a clear
//! error from every entry point that would need the PJRT plugin.
//!
//! Every caller in `sotb_bic` already treats PJRT as optional (tests and
//! benches skip when the artifact manifest is absent; the CLI reports the
//! error), so swapping this stub for the real bindings is a one-line
//! `Cargo.toml` change and zero source changes.

#![allow(dead_code)]

use std::fmt;

/// The error every stubbed entry point returns.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT is unavailable (built against the vendored `xla` \
             stub; point Cargo.toml at the real xla_extension bindings to \
             execute AOT artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub of a host literal (a typed, shaped constant buffer).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a slice (stub: shape/content dropped).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Stub of a device buffer returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a parsed HLO module proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Stub of a compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Stub of the PJRT client.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3]).is_ok(), "shape ops are pure metadata");
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn errors_name_the_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"), "{e}");
    }
}
