//! Hot-path microbenchmarks — the L3 performance-pass instrument
//! (EXPERIMENTS.md §Perf): bitmap algebra (incl. the fused multi-operand
//! kernel), the 64x64 block transpose vs the scalar reference, packed CAM
//! matching, WAH, the query engine, the golden indexing core, the
//! thread-sharded coordinator path, the cycle simulator, the
//! multi-tenant service tier under contention, and PJRT artifact
//! dispatch.
//!
//! Results are also emitted machine-readable to `BENCH_hotpath.json`
//! (one object per case) so the perf trajectory is tracked across PRs.
//!
//! `BENCH_SMOKE=1` switches to the short CI mode: identical cases and
//! names, reduced warmup/sample budget, smaller compressed-query corpus —
//! the bench-smoke CI job compares its output against the committed
//! `BENCH_baseline.json` (see `ci.sh --bench`).

use sotb_bic::baselines::SoftwareIndexer;
use sotb_bic::bic::kernel;
use sotb_bic::bic::transpose::{pack_rows, transpose, transpose_packed};
use sotb_bic::bic::{
    BicConfig, BicCore, Bitmap, Cam, CompressedIndex, Query, WahBitmap,
};
use sotb_bic::coordinator::{ContentDist, ShardedIndexer, WorkloadGen};
use sotb_bic::runtime::{BicExecutable, Manifest, Runtime};
use sotb_bic::sim::CoreSim;
use sotb_bic::substrate::bench::{group, smoke_mode, Bench, BenchResult};
use sotb_bic::substrate::json::Json;
use sotb_bic::substrate::rng::Xoshiro256;

/// A bench under the mode-appropriate measurement budget.
fn bench(name: impl Into<String>) -> Bench {
    Bench::auto(name)
}

fn random_batch(rng: &mut Xoshiro256, n: usize, w: usize) -> Vec<Vec<i32>> {
    (0..n).map(|_| (0..w).map(|_| rng.next_below(256) as i32).collect()).collect()
}

fn main() {
    let mut rng = Xoshiro256::seeded(0x1407);
    let mut results: Vec<BenchResult> = Vec::new();

    group("bitmap algebra (1 Mbit rows)");
    let nbits = 1 << 20;
    let mut a = Bitmap::zeros(nbits);
    let mut b = Bitmap::zeros(nbits);
    for _ in 0..nbits / 16 {
        a.set(rng.next_below(nbits as u64) as usize, true);
        b.set(rng.next_below(nbits as u64) as usize, true);
    }
    results.push(
        bench("bitmap/and-1Mbit").bytes((nbits / 8) as u64).run(|| a.and(&b)),
    );
    let mut acc = a.clone();
    results.push(
        bench("bitmap/and_assign-1Mbit")
            .bytes((nbits / 8) as u64)
            .run(|| acc.and_assign(&b)),
    );
    // Fused 4-operand conjunction vs the chained pairwise equivalent, on
    // ~50%-dense rows so essentially no block dies and the pair measures
    // kernel fusion (fewer passes), not the zero-block skip.
    let dense: Vec<Bitmap> = (0..4)
        .map(|_| {
            let bools: Vec<bool> =
                (0..nbits).map(|_| rng.chance(0.5)).collect();
            Bitmap::from_bools(&bools)
        })
        .collect();
    let (d0, d1, d2, d3) = (&dense[0], &dense[1], &dense[2], &dense[3]);
    results.push(
        bench("bitmap/and_all-4x1Mbit-dense")
            .bytes((4 * nbits / 8) as u64)
            .run(|| d0.and_all(&[d1, d2, d3])),
    );
    results.push(
        bench("bitmap/and-chained-4x1Mbit-dense")
            .bytes((4 * nbits / 8) as u64)
            .run(|| d0.and(d1).and(d2).and(d3)),
    );
    // Selective case: the sparse a & b kills most blocks early, so this
    // measures the absorbing-zero skip path (bytes denominator omitted —
    // the point is that most memory is deliberately never touched).
    results.push(
        bench("bitmap/and_all-4x1Mbit-selective")
            .run(|| a.and_all(&[&b, d0, d1])),
    );
    results.push(
        bench("bitmap/count_ones-1Mbit")
            .bytes((nbits / 8) as u64)
            .run(|| a.count_ones()),
    );

    // Scalar-vs-dispatched pairs over the raw kernel table: the same
    // word slices through `kernel::SCALAR` and through whatever tier
    // runtime dispatch selected (identical when the host lacks AVX2 or
    // PALLAS_KERNEL_TIER=scalar is set — the pair then measures noise).
    group("kernel tier (scalar vs dispatched, 1 Mbit)");
    println!("active kernel tier: {}", kernel::tier().label());
    let nw = nbits / 64;
    let ksrc: Vec<u64> = (0..nw).map(|_| rng.next_u64()).collect();
    let mut kdst: Vec<u64> = (0..nw).map(|_| rng.next_u64()).collect();
    let kt = kernel::table();
    results.push(
        bench("kernel/and-1Mbit-scalar")
            .bytes((nbits / 8) as u64)
            .run(|| (kernel::SCALAR.and)(&mut kdst, &ksrc)),
    );
    results.push(
        bench("kernel/and-1Mbit")
            .bytes((nbits / 8) as u64)
            .run(|| (kt.and)(&mut kdst, &ksrc)),
    );
    results.push(
        bench("kernel/or-1Mbit-scalar")
            .bytes((nbits / 8) as u64)
            .run(|| (kernel::SCALAR.or)(&mut kdst, &ksrc)),
    );
    results.push(
        bench("kernel/or-1Mbit")
            .bytes((nbits / 8) as u64)
            .run(|| (kt.or)(&mut kdst, &ksrc)),
    );
    results.push(
        bench("kernel/count_ones-1Mbit-scalar")
            .bytes((nbits / 8) as u64)
            .run(|| (kernel::SCALAR.count_ones)(&ksrc)),
    );
    results.push(
        bench("kernel/count_ones-1Mbit")
            .bytes((nbits / 8) as u64)
            .run(|| (kt.count_ones)(&ksrc)),
    );
    let mut tile = [0u64; 64];
    for (i, w) in tile.iter_mut().enumerate() {
        *w = ksrc[i];
    }
    results.push(
        bench("kernel/transpose64-scalar")
            .bytes(64 * 8)
            .run(|| (kernel::SCALAR.transpose64)(&mut tile)),
    );
    results.push(
        bench("kernel/transpose64")
            .bytes(64 * 8)
            .run(|| (kt.transpose64)(&mut tile)),
    );
    results.push(
        bench("kernel/wah-compress-1Mbit-scalar")
            .bytes((nbits / 8) as u64)
            .run(|| WahBitmap::compress_with(&a, &kernel::SCALAR)),
    );
    results.push(
        bench("kernel/wah-compress-1Mbit")
            .bytes((nbits / 8) as u64)
            .run(|| WahBitmap::compress_with(&a, kt)),
    );

    group("transpose (4096 records x 64 keys)");
    let (tn, tm) = (4096usize, 64usize);
    let tbits: Vec<bool> =
        (0..tn * tm).map(|_| rng.next_below(4) == 0).collect();
    let tpacked = pack_rows(&tbits, tn, tm);
    let tbytes = (tn * tm / 8) as u64;
    results.push(
        bench("transpose/scalar-4096x64")
            .bytes(tbytes)
            .run(|| transpose(&tbits, tn, tm)),
    );
    results.push(
        bench("transpose/block64-4096x64")
            .bytes(tbytes)
            .run(|| transpose_packed(&tpacked, tn, tm)),
    );

    group("CAM matching (32-word record, 256 keys)");
    let mut cam = Cam::new(32);
    cam.load(&(0..32).map(|_| rng.next_below(256) as i32).collect::<Vec<_>>());
    let many_keys: Vec<i32> =
        (0..256).map(|_| rng.next_below(256) as i32).collect();
    let mut match_row = vec![0u64; 4];
    results.push(
        bench("cam/match_all-256keys")
            .bytes(256)
            .run(|| cam.match_all(&many_keys)),
    );
    results.push(
        bench("cam/match_packed-256keys")
            .bytes(256)
            .run(|| cam.match_packed_into(&many_keys, &mut match_row)),
    );

    group("WAH compression (1 Mbit, sparse)");
    let wah_a = WahBitmap::compress(&a);
    let wah_b = WahBitmap::compress(&b);
    println!("compression ratio: {:.1}x", wah_a.ratio());
    results.push(
        bench("wah/compress").bytes((nbits / 8) as u64).run(|| WahBitmap::compress(&a)),
    );
    results.push(bench("wah/and-compressed").run(|| wah_a.and(&wah_b)));
    results.push(bench("wah/count_ones").run(|| wah_a.count_ones()));

    group("indexing cores (chip geometry: 16x32, 8 keys)");
    let recs = random_batch(&mut rng, 16, 32);
    let keys: Vec<i32> = (0..8).map(|_| rng.next_below(256) as i32).collect();
    let mut golden = BicCore::new(BicConfig::CHIP);
    results.push(
        bench("index/golden-model")
            .bytes(512)
            .run(|| golden.index(&recs, &keys)),
    );
    results.push(
        bench("index/scalar-reference")
            .bytes(512)
            .run(|| golden.index_scalar(&recs, &keys)),
    );
    let mut sim = CoreSim::new(BicConfig::CHIP);
    results.push(
        bench("index/cycle-simulator")
            .bytes(512)
            .run(|| sim.index_batch(&recs, &keys)),
    );
    let sw = SoftwareIndexer::new(8);
    results.push(
        bench("index/software-baseline")
            .bytes(512)
            .run(|| sw.index(&recs, &keys)),
    );

    group("sharded coordinator (256 chip batches)");
    let mut wg = WorkloadGen::new(BicConfig::CHIP, ContentDist::Uniform, 0x51AD);
    let trace: Vec<_> = (0..256).map(|i| wg.batch_at(i as f64)).collect();
    let trace_bytes: u64 =
        trace.iter().map(|b| b.input_bytes() as u64).sum();
    let serial = ShardedIndexer::new(BicConfig::CHIP, 1).expect("one shard");
    results.push(
        bench("index/sharded-1core-256batches")
            .bytes(trace_bytes)
            .run(|| serial.index_batches(&trace).expect("valid trace")),
    );
    let parallel = ShardedIndexer::with_host_parallelism(BicConfig::CHIP);
    if parallel.shards() > 1 {
        results.push(
            bench(format!(
                "index/sharded-{}core-256batches",
                parallel.shards()
            ))
            .bytes(trace_bytes)
            .run(|| parallel.index_batches(&trace).expect("valid trace")),
        );
    } else {
        println!("(single-core host: parallel shard case skipped)");
    }

    group("query engine (64 attrs x 1M objects)");
    let mut qrng = Xoshiro256::seeded(7);
    let rows: Vec<Bitmap> = (0..64)
        .map(|_| {
            let mut r = Bitmap::zeros(1 << 20);
            for _ in 0..(1 << 14) {
                r.set(qrng.next_below(1 << 20) as usize, true);
            }
            r
        })
        .collect();
    let bi = sotb_bic::bic::BitmapIndex::from_rows(rows);
    let q = Query::attr(1).and(Query::attr(5)).and(Query::attr(9).not());
    results.push(bench("query/and-and-not-1Mobj").run(|| q.eval(&bi).unwrap()));

    // Compressed-execution tier: the same query class on an adaptively
    // compressed index, paired against decompress-then-evaluate, across
    // all three content distributions (the clustered one is WAH's home
    // turf and the headline win).
    group("compressed query tier (262k objects per distribution)");
    let cq = Query::attr(1)
        .and(Query::attr(3))
        .and(Query::attr(7))
        .and(Query::attr(5).not());
    for (dist_name, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 16 }),
    ] {
        let cfg = BicConfig { n_records: 256, w_words: 8, m_keys: 16 };
        let nbatches = if smoke_mode() { 256 } else { 1024 };
        let cbi = WorkloadGen::new(cfg, dist, 0xC0DE).attribute_rows(nbatches);
        let ci = CompressedIndex::from_index(&cbi);
        let h = ci.codec_histogram();
        println!(
            "{dist_name}: ratio {:.2}x, codecs raw/wah/roaring {}/{}/{}",
            ci.ratio(),
            h[0],
            h[1],
            h[2]
        );
        // Differential pin before timing: the planner must match the
        // uncompressed reference bit for bit.
        assert_eq!(
            cq.eval_compressed(&ci).unwrap(),
            cq.eval(&cbi).unwrap(),
            "{dist_name}: compressed eval diverged"
        );
        let row_bytes = (ci.num_attrs() * ci.num_objects() / 8) as u64;
        results.push(
            bench(format!("cquery/{dist_name}-decompress-then-eval"))
                .bytes(row_bytes)
                .run(|| cq.eval(&ci.to_index()).unwrap()),
        );
        results.push(
            bench(format!("cquery/{dist_name}-compressed-eval"))
                .bytes(row_bytes)
                .run(|| cq.eval_compressed(&ci).unwrap()),
        );
    }

    // Engine facade end to end: the session-API ack path (index + codec
    // encode + WAL fsync), the planned query path over a store spanning
    // segments + a memtable tail, and the full
    // ingest->flush->query lifecycle. Everything constructs through
    // `EngineBuilder`; fresh tmpdir per ingest/e2e iteration so every
    // run pays the real create/append/flush cost.
    group("engine facade (16 attrs x 64 batches of 256 objects, durable)");
    {
        use sotb_bic::engine::{Engine, EngineBuilder, ExecPath, Schema};
        let ecfg = BicConfig { n_records: 256, w_words: 8, m_keys: 16 };
        let nbatches = if smoke_mode() { 16 } else { 64 };
        let mut sg =
            WorkloadGen::new(ecfg, ContentDist::Clustered { spread: 16 }, 0x57);
        let batch_records: Vec<Vec<Vec<i32>>> =
            (0..nbatches).map(|i| sg.batch_at(i as f64).records).collect();
        let input_bytes: u64 =
            (nbatches * ecfg.n_records * ecfg.w_words) as u64;
        let index_bytes: u64 =
            (nbatches * ecfg.n_records / 8 * ecfg.m_keys) as u64;
        let bench_root = std::env::temp_dir()
            .join(format!("bic-engine-bench-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&bench_root);
        std::fs::create_dir_all(&bench_root).expect("bench tmpdir");
        // 12 divides neither batch count, so the query engine always has
        // both segments and a memtable tail to span.
        let build = |dir: &std::path::Path| -> Engine {
            EngineBuilder::new(
                Schema::single("byte", 0..ecfg.m_keys as i32)
                    .expect("schema"),
            )
            .batch_records(ecfg.n_records)
            .record_words(ecfg.w_words)
            .durable(dir)
            .flush_batches(12)
            .build()
            .expect("engine")
        };
        let mut iter = 0u64;
        results.push(bench("engine/ingest").bytes(input_bytes).run(|| {
            iter += 1;
            let dir = bench_root.join(format!("ingest-{iter}"));
            let engine = build(&dir);
            for records in &batch_records {
                engine.ingest(records).expect("ingest");
            }
            let bytes = engine.stats().segment_bytes_written;
            drop(engine);
            let _ = std::fs::remove_dir_all(&dir);
            bytes
        }));
        // Paired async case: the same trace through the pipelined
        // ingest stage — encode workers overlap the appender, and runs
        // of batches share one WAL group-commit fsync instead of one
        // fsync per batch.
        let mut aiter = 0u64;
        results.push(bench("engine/ingest_async").bytes(input_bytes).run(
            || {
                aiter += 1;
                let dir = bench_root.join(format!("ingest-async-{aiter}"));
                let engine = build(&dir);
                let tickets = engine
                    .ingest_batches_async(batch_records.clone())
                    .expect("submit");
                for t in tickets {
                    t.wait().expect("receipt");
                }
                let bytes = engine.stats().segment_bytes_written;
                drop(engine);
                let _ = std::fs::remove_dir_all(&dir);
                bytes
            },
        ));
        // Query pair: segments + memtable tail through the planner,
        // zone maps on (`engine/query_pruned`) vs the same store
        // reopened with pruning off (`engine/query`, the historical
        // baseline semantics). Clustered content means most segments
        // carry provably-zero rows for the queried keys, so pruning
        // skips them; the byte counters make the difference exact.
        let qdir = bench_root.join("query");
        let qengine = build(&qdir);
        for records in &batch_records {
            qengine.ingest(records).expect("ingest");
        }
        let sq = Query::attr(1)
            .and(Query::attr(3))
            .and(Query::attr(7))
            .and(Query::attr(5).not());
        // Differential pin before timing: all four tiers bit-identical.
        let pin = qengine.query_via(&sq, ExecPath::Raw).expect("raw");
        for path in ExecPath::ALL {
            assert_eq!(
                qengine.query_via(&sq, path).expect("query"),
                pin,
                "{path:?} diverged"
            );
        }
        results.push(
            bench("engine/query_pruned")
                .bytes(index_bytes)
                .run(|| qengine.query(&sq).unwrap()),
        );
        let pruned_stats = qengine.stats();
        drop(qengine);
        let qengine_noskip = EngineBuilder::new(
            Schema::single("byte", 0..ecfg.m_keys as i32).expect("schema"),
        )
        .batch_records(ecfg.n_records)
        .record_words(ecfg.w_words)
        .durable(&qdir)
        .flush_batches(12)
        .zone_maps(false)
        .build()
        .expect("reopen without pruning");
        let noskip_pin =
            qengine_noskip.query_via(&sq, ExecPath::Raw).expect("raw");
        assert_eq!(noskip_pin, pin, "pruning off must not change bits");
        for path in ExecPath::ALL {
            assert_eq!(
                qengine_noskip.query_via(&sq, path).expect("query"),
                pin,
                "{path:?} diverged with pruning off"
            );
        }
        results.push(
            bench("engine/query")
                .bytes(index_bytes)
                .run(|| qengine_noskip.query(&sq).unwrap()),
        );
        let noskip_stats = qengine_noskip.stats();
        println!(
            "zone pruning: {} row bytes folded / {} windows skipped \
             (pruned) vs {} row bytes folded (noskip)",
            pruned_stats.store_row_bytes_read,
            pruned_stats.store_chunks_skipped,
            noskip_stats.store_row_bytes_read
        );
        drop(qengine_noskip);
        // Telemetry-on twin of `engine/query_pruned`: the same store
        // reopened with histograms + stage traces live. Comparing its
        // mean against `engine/query_pruned` is the instrumentation
        // overhead (the disabled path must stay within ~2% of the
        // seed; the enabled path pays one clock read + a few relaxed
        // atomics per query). The counter asserts pin that telemetry
        // actually recorded — a silently dead histogram would make the
        // "overhead" number meaningless.
        let qengine_telem = EngineBuilder::new(
            Schema::single("byte", 0..ecfg.m_keys as i32).expect("schema"),
        )
        .batch_records(ecfg.n_records)
        .record_words(ecfg.w_words)
        .durable(&qdir)
        .flush_batches(12)
        .telemetry(true)
        .build()
        .expect("reopen with telemetry");
        assert_eq!(
            qengine_telem.query(&sq).expect("query"),
            pin,
            "telemetry on must not change bits"
        );
        results.push(
            bench("engine/query_telemetry")
                .bytes(index_bytes)
                .run(|| qengine_telem.query(&sq).unwrap()),
        );
        let telem = qengine_telem.telemetry().expect("telemetry handle");
        let recorded: u64 = telem.query.iter().map(|h| h.count()).sum();
        assert!(recorded > 0, "query histogram recorded nothing");
        assert!(
            telem.query_bytes.count() > 0,
            "query_bytes histogram recorded nothing"
        );
        let snap = telem
            .query
            .iter()
            .map(|h| h.snapshot())
            .max_by_key(|s| s.count)
            .expect("four tiers");
        println!(
            "telemetry: {recorded} queries recorded, busiest tier \
             p50={} p99={} max={} cycles",
            snap.quantile(0.5),
            snap.quantile(0.99),
            snap.max
        );
        drop(qengine_telem);
        // Full lifecycle: build -> ingest -> flush -> query -> close.
        let mut e2e_iter = 0u64;
        results.push(bench("engine/e2e").bytes(input_bytes).run(|| {
            e2e_iter += 1;
            let dir = bench_root.join(format!("e2e-{e2e_iter}"));
            let engine = build(&dir);
            for records in &batch_records {
                engine.ingest(records).expect("ingest");
            }
            engine.flush().expect("flush");
            let hits = engine.query(&sq).expect("query").count_ones();
            engine.close().expect("close");
            let _ = std::fs::remove_dir_all(&dir);
            hits
        }));
        let _ = std::fs::remove_dir_all(&bench_root);
    }

    // Bit-sliced tier: range selection through the O(log span) slice
    // circuit vs the same predicate forced onto the O(domain)
    // OR-expansion (an engine built with `.bsi(false)`), plus the
    // weighted-popcount aggregate vs its per-value fallback. Domain 256
    // — wide enough that the expansion touches two orders of magnitude
    // more rows than the 9 slice bitmaps. Bit-identity is pinned across
    // all three content distributions before anything is timed; the
    // timed pair runs on the uniform trace.
    group("bit-sliced tier (1 column x domain 256, in-memory)");
    {
        use sotb_bic::engine::{col, AggFn, Engine, EngineBuilder, Schema};
        // One word per record: the column is single-valued per record,
        // so every chunk builds its slices (multi-valued chunks decline
        // BSI and would fall back to the very expansion being paired).
        let ecfg = BicConfig { n_records: 256, w_words: 1, m_keys: 256 };
        let nbatches = if smoke_mode() { 8 } else { 32 };
        let build = |bsi: bool| -> Engine {
            EngineBuilder::new(Schema::single("v", 0..256).expect("schema"))
                .batch_records(ecfg.n_records)
                .record_words(ecfg.w_words)
                .bsi(bsi)
                .build()
                .expect("engine")
        };
        let range = col("v").between(64, 191);
        let pins = [
            col("v").ge(200),
            col("v").le(40),
            col("v").between(64, 191),
            col("v").between(0, 255),
        ];
        let mut timed: Option<(Engine, Engine)> = None;
        for (dist_name, dist) in [
            ("uniform", ContentDist::Uniform),
            ("zipf", ContentDist::Zipf { s: 1.2 }),
            ("clustered", ContentDist::Clustered { spread: 16 }),
        ] {
            let slice = build(true);
            let orexp = build(false);
            let mut wg = WorkloadGen::new(ecfg, dist, 0xB51);
            for i in 0..nbatches {
                let records = wg.batch_at(i as f64).records;
                slice.ingest(&records).expect("ingest slice");
                orexp.ingest(&records).expect("ingest orexp");
            }
            // Differential pin: the slice circuit must match the
            // OR-expansion bit for bit on every predicate shape.
            for p in &pins {
                assert_eq!(
                    slice.select(p).expect("slice select"),
                    orexp.select(p).expect("orexp select"),
                    "{dist_name}: slice circuit diverged on {p:?}"
                );
                assert_eq!(
                    slice.aggregate("v", AggFn::Sum, Some(p)).expect("agg"),
                    orexp.aggregate("v", AggFn::Sum, Some(p)).expect("agg"),
                    "{dist_name}: aggregate diverged on {p:?}"
                );
            }
            assert_eq!(
                slice.top_k("v", 16, Some(&range)).expect("topk"),
                orexp.top_k("v", 16, Some(&range)).expect("topk"),
                "{dist_name}: top_k diverged"
            );
            assert!(
                slice.stats().queries_bsi > 0,
                "{dist_name}: planner never took the bsi tier"
            );
            if dist_name == "uniform" {
                timed = Some((slice, orexp));
            }
        }
        let (slice, orexp) = timed.expect("uniform pair");
        let objects = slice.stats().objects;
        // Bytes folded per evaluation: 9 slice bitmaps (8 + presence)
        // vs the 128 expanded attribute rows of `between(64, 191)`.
        let row_bytes = (objects / 8) as u64;
        results.push(
            bench("bsi/range")
                .bytes(9 * row_bytes)
                .run(|| slice.select(&range).unwrap()),
        );
        results.push(
            bench("bsi/range-orexpand")
                .bytes(128 * row_bytes)
                .run(|| orexp.select(&range).unwrap()),
        );
        results.push(
            bench("bsi/aggregate").bytes(9 * row_bytes).run(|| {
                slice.aggregate("v", AggFn::Sum, Some(&range)).unwrap()
            }),
        );
        results.push(
            bench("bsi/aggregate-fallback").bytes(128 * row_bytes).run(
                || orexp.aggregate("v", AggFn::Sum, Some(&range)).unwrap(),
            ),
        );
        results.push(
            bench("bsi/topk")
                .bytes(9 * row_bytes)
                .run(|| slice.top_k("v", 16, Some(&range)).unwrap()),
        );
        println!(
            "bsi: {objects} objects, {} bsi-tier queries recorded",
            slice.stats().queries_bsi
        );
    }

    // Service-tier contention: one in-process server, N worker threads
    // with persistent line-protocol clients over loopback, each doing
    // sync-ingest + query rounds against a shared tenant. The sample
    // clock wraps a whole concurrent round (barrier to barrier), so
    // `per_iter` is the aggregate per-op latency under contention —
    // registry lookups, per-tenant engine locking, and the socket round
    // trip included. `busy` answers are retried and counted, never
    // fatal (with a sync client per worker the in-flight bound is never
    // the limiter; the count proves it).
    group("service tier (4 workers, ingest+query over loopback)");
    {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::time::Instant;

        use sotb_bic::bic::clock;
        use sotb_bic::obs::Histogram;
        use sotb_bic::server::client::Client;
        use sotb_bic::server::protocol::{response_error_code, response_ok};
        use sotb_bic::server::Server;
        use sotb_bic::substrate::stats::Summary;

        const WORKERS: usize = 4;
        let rounds = if smoke_mode() { 8 } else { 48 };
        let nsamples = if smoke_mode() { 3 } else { 8 };
        let root = std::env::temp_dir()
            .join(format!("bic-serve-bench-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let handle = Server::bind(&root, "127.0.0.1:0", WORKERS + 4)
            .expect("bind")
            .spawn();
        let addr = handle.local_addr();
        let mut admin = Client::connect(addr).expect("admin connect");
        let schema = Json::obj([(
            "columns",
            Json::Arr(vec![Json::obj([
                ("name", "k".into()),
                ("values", (0..16).collect::<Vec<i32>>().into()),
            ])]),
        )]);
        let tcfg = Json::obj([
            ("batch_records", 64.into()),
            ("record_words", 8.into()),
            ("flush_batches", 8.into()),
        ]);
        let resp = admin
            .create_tenant("bench", &schema, Some(&tcfg))
            .expect("create_tenant");
        assert!(response_ok(&resp), "create_tenant: {}", resp.render());
        let batch: Vec<Vec<i32>> = (0..64)
            .map(|r| (0..8).map(|w| ((r + w) % 16) as i32).collect())
            .collect();
        let predicate =
            Json::obj([("col", "k".into()), ("eq", 3.into())]);
        let total_ops = (WORKERS * rounds * 2) as u64;
        let busy_retries = AtomicU64::new(0);
        // Per-op wall latency across every worker (busy retries
        // included): the histogram's atomic buckets make it shareable
        // by reference, and its quantiles land in the JSON case under
        // `extra` so the perf trajectory tracks tail latency, not just
        // the mean.
        let latency = Histogram::new();
        let barrier = std::sync::Barrier::new(WORKERS + 1);
        let mut sample_times: Vec<f64> = Vec::with_capacity(nsamples);
        std::thread::scope(|s| {
            for _ in 0..WORKERS {
                let (barrier, busy) = (&barrier, &busy_retries);
                let (batch, predicate) = (&batch, &predicate);
                let latency = &latency;
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("worker");
                    for _ in 0..nsamples {
                        barrier.wait();
                        for _ in 0..rounds {
                            let t0 = Instant::now();
                            loop {
                                let r = c
                                    .ingest("bench", batch, true)
                                    .expect("ingest transport");
                                if response_ok(&r) {
                                    break;
                                }
                                assert_eq!(
                                    response_error_code(&r),
                                    Some("busy"),
                                    "ingest: {}",
                                    r.render()
                                );
                                busy.fetch_add(1, Ordering::Relaxed);
                                std::thread::yield_now();
                            }
                            latency.record(clock::to_cycles(t0.elapsed()));
                            let t0 = Instant::now();
                            let r = c
                                .query("bench", predicate)
                                .expect("query transport");
                            assert!(response_ok(&r), "query: {}", r.render());
                            latency.record(clock::to_cycles(t0.elapsed()));
                        }
                        barrier.wait();
                    }
                });
            }
            for _ in 0..nsamples {
                barrier.wait();
                let t0 = Instant::now();
                barrier.wait();
                sample_times.push(t0.elapsed().as_secs_f64());
            }
        });
        let per_op: Vec<f64> =
            sample_times.iter().map(|t| t / total_ops as f64).collect();
        let lat = latency.snapshot();
        let contention = BenchResult {
            name: "engine/contention".into(),
            per_iter: Summary::of(&per_op),
            iters_per_sample: total_ops,
            // Bytes in per op pair, averaged over the ingest+query mix.
            bytes_per_iter: Some((64 * 8 * 4) / 2),
            extra: Some(Json::obj([
                ("lat_p50_ns", lat.quantile(0.5).into()),
                ("lat_p90_ns", lat.quantile(0.9).into()),
                ("lat_p99_ns", lat.quantile(0.99).into()),
                ("lat_max_ns", lat.max.into()),
                ("lat_count", lat.count.into()),
            ])),
        };
        println!("{}", contention.line());
        let mean_round = sample_times.iter().sum::<f64>()
            / sample_times.len().max(1) as f64;
        println!(
            "contention: {WORKERS} workers x {rounds} rounds, \
             {:.0} ops/sec/worker, {:.0} ops/sec total, {} busy retries, \
             lat p50={} p99={} max={} us",
            (rounds * 2) as f64 / mean_round,
            total_ops as f64 / mean_round,
            busy_retries.load(Ordering::Relaxed),
            lat.quantile(0.5) / 1_000,
            lat.quantile(0.99) / 1_000,
            lat.max / 1_000,
        );
        results.push(contention);
        drop(admin);
        handle.stop();
        let _ = std::fs::remove_dir_all(&root);
    }

    group("PJRT artifact dispatch");
    let dir = Manifest::default_dir();
    if dir.join("manifest.txt").exists() {
        let manifest = Manifest::load(&dir).expect("manifest");
        let rt = Runtime::cpu().expect("PJRT client");
        for name in ["chip", "batch", "large"] {
            let v = manifest.find_bic(name).expect("variant");
            let exe = BicExecutable::load(&rt, v).expect("compile");
            let mut vrng = Xoshiro256::seeded(name.len() as u64);
            let recs = random_batch(&mut vrng, v.n, v.w);
            let keys: Vec<i32> =
                (0..v.m).map(|_| vrng.next_below(256) as i32).collect();
            results.push(
                bench(format!("pjrt/index-{name} (n={} w={} m={})", v.n, v.w, v.m))
                    .bytes((v.n * v.w) as u64)
                    .run(|| exe.index(&recs, &keys).unwrap()),
            );
        }
    } else {
        println!("(skipped: run `make artifacts` first)");
    }

    // Machine-readable dump for cross-PR perf tracking.
    let json = Json::obj([(
        "hotpath",
        Json::Arr(results.iter().map(BenchResult::to_json).collect()),
    )]);
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, json.render() + "\n") {
        Ok(()) => println!("\nwrote {} results to {path}", results.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
