//! Hot-path microbenchmarks — the L3 performance-pass instrument
//! (EXPERIMENTS.md §Perf): bitmap algebra, WAH, query engine, the golden
//! indexing core, the cycle simulator, and PJRT artifact dispatch.

use sotb_bic::baselines::SoftwareIndexer;
use sotb_bic::bic::{BicConfig, BicCore, Bitmap, Query, WahBitmap};
use sotb_bic::runtime::{BicExecutable, Manifest, Runtime};
use sotb_bic::sim::CoreSim;
use sotb_bic::substrate::bench::{group, Bench};
use sotb_bic::substrate::rng::Xoshiro256;

fn random_batch(rng: &mut Xoshiro256, n: usize, w: usize) -> Vec<Vec<i32>> {
    (0..n).map(|_| (0..w).map(|_| rng.next_below(256) as i32).collect()).collect()
}

fn main() {
    let mut rng = Xoshiro256::seeded(0x1407);

    group("bitmap algebra (1 Mbit rows)");
    let nbits = 1 << 20;
    let mut a = Bitmap::zeros(nbits);
    let mut b = Bitmap::zeros(nbits);
    for _ in 0..nbits / 16 {
        a.set(rng.next_below(nbits as u64) as usize, true);
        b.set(rng.next_below(nbits as u64) as usize, true);
    }
    Bench::new("bitmap/and-1Mbit").bytes((nbits / 8) as u64).run(|| a.and(&b));
    let mut acc = a.clone();
    Bench::new("bitmap/and_assign-1Mbit")
        .bytes((nbits / 8) as u64)
        .run(|| acc.and_assign(&b));
    Bench::new("bitmap/count_ones-1Mbit")
        .bytes((nbits / 8) as u64)
        .run(|| a.count_ones());

    group("WAH compression (1 Mbit, sparse)");
    let wah_a = WahBitmap::compress(&a);
    let wah_b = WahBitmap::compress(&b);
    println!("compression ratio: {:.1}x", wah_a.ratio());
    Bench::new("wah/compress").bytes((nbits / 8) as u64).run(|| WahBitmap::compress(&a));
    Bench::new("wah/and-compressed").run(|| wah_a.and(&wah_b));
    Bench::new("wah/count_ones").run(|| wah_a.count_ones());

    group("indexing cores (chip geometry: 16x32, 8 keys)");
    let recs = random_batch(&mut rng, 16, 32);
    let keys: Vec<i32> = (0..8).map(|_| rng.next_below(256) as i32).collect();
    let mut golden = BicCore::new(BicConfig::CHIP);
    Bench::new("index/golden-model")
        .bytes(512)
        .run(|| golden.index(&recs, &keys));
    let mut sim = CoreSim::new(BicConfig::CHIP);
    Bench::new("index/cycle-simulator")
        .bytes(512)
        .run(|| sim.index_batch(&recs, &keys));
    let sw = SoftwareIndexer::new(8);
    Bench::new("index/software-baseline")
        .bytes(512)
        .run(|| sw.index(&recs, &keys));

    group("query engine (64 attrs x 1M objects)");
    let mut qrng = Xoshiro256::seeded(7);
    let rows: Vec<Bitmap> = (0..64)
        .map(|_| {
            let mut r = Bitmap::zeros(1 << 20);
            for _ in 0..(1 << 14) {
                r.set(qrng.next_below(1 << 20) as usize, true);
            }
            r
        })
        .collect();
    let bi = sotb_bic::bic::BitmapIndex::from_rows(rows);
    let q = Query::attr(1).and(Query::attr(5)).and(Query::attr(9).not());
    Bench::new("query/and-and-not-1Mobj").run(|| q.eval(&bi).unwrap());

    group("PJRT artifact dispatch");
    let dir = Manifest::default_dir();
    if dir.join("manifest.txt").exists() {
        let manifest = Manifest::load(&dir).expect("manifest");
        let rt = Runtime::cpu().expect("PJRT client");
        for name in ["chip", "batch", "large"] {
            let v = manifest.find_bic(name).expect("variant");
            let exe = BicExecutable::load(&rt, v).expect("compile");
            let mut vrng = Xoshiro256::seeded(name.len() as u64);
            let recs = random_batch(&mut vrng, v.n, v.w);
            let keys: Vec<i32> =
                (0..v.m).map(|_| vrng.next_below(256) as i32).collect();
            Bench::new(format!("pjrt/index-{name} (n={} w={} m={})", v.n, v.w, v.m))
                .bytes((v.n * v.w) as u64)
                .run(|| exe.index(&recs, &keys).unwrap());
        }
    } else {
        println!("(skipped: run `make artifacts` first)");
    }
}
