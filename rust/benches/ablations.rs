//! Design-choice ablations (DESIGN.md calls these out):
//!   1. kernel fusion — fused match+pack vs the two-step artifact;
//!   2. hardware formulation — VPU compare-reduce vs MXU one-hot matmul;
//!   3. dispatch coalescing — 4 batches per PJRT call vs 4 calls;
//!   4. compression — WAH vs roaring vs raw on the three content
//!      distributions.

use sotb_bic::bic::{BicConfig, Bitmap, RoaringBitmap, WahBitmap};
use sotb_bic::coordinator::{ContentDist, WorkloadGen};
use sotb_bic::runtime::{BicExecutable, Manifest, Runtime};
use sotb_bic::substrate::bench::{group, Bench};
use sotb_bic::substrate::rng::Xoshiro256;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        println!("run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let rt = Runtime::cpu().expect("PJRT");

    // --- 1+2: fusion & formulation, on the batch geometry. ---
    group("ablation: kernel fusion & formulation (batch: 256x32, 16 keys)");
    let fused_v = manifest.find_bic("batch").unwrap();
    let twostep_v = manifest.find_twostep("batch").unwrap();
    let mxu_v = manifest.find_mxu("batch").unwrap();
    let mut rng = Xoshiro256::seeded(1);
    let recs: Vec<Vec<i32>> = (0..fused_v.n)
        .map(|_| (0..fused_v.w).map(|_| rng.next_below(256) as i32).collect())
        .collect();
    let keys: Vec<i32> =
        (0..fused_v.m).map(|_| rng.next_below(256) as i32).collect();
    let bytes = (fused_v.n * fused_v.w) as u64;
    for (label, v) in [("fused", fused_v), ("twostep", twostep_v), ("mxu", mxu_v)] {
        let exe = BicExecutable::load(&rt, v).expect("compile");
        // All three must agree before we time them.
        let out = exe.index(&recs, &keys).unwrap();
        let fused_exe = BicExecutable::load(&rt, fused_v).unwrap();
        assert_eq!(out, fused_exe.index(&recs, &keys).unwrap(), "{label}");
        Bench::new(format!("pjrt/{label}"))
            .bytes(bytes)
            .run(|| exe.index(&recs, &keys).unwrap());
    }

    // --- 3: dispatch coalescing. ---
    group("ablation: dispatch coalescing (4 batches)");
    let co_v = manifest.find_coalesce("batch").unwrap();
    let exe_one = BicExecutable::load(&rt, fused_v).unwrap();
    let exe_co = BicExecutable::load(&rt, co_v).unwrap();
    let batches: Vec<Vec<Vec<i32>>> = (0..4)
        .map(|_| {
            (0..co_v.n)
                .map(|_| (0..co_v.w).map(|_| rng.next_below(256) as i32).collect())
                .collect()
        })
        .collect();
    let batch_refs: Vec<&[Vec<i32>]> = batches.iter().map(|b| b.as_slice()).collect();
    Bench::new("dispatch/4-separate-calls")
        .bytes(4 * bytes)
        .run(|| {
            batches
                .iter()
                .map(|b| exe_one.index(b, &keys).unwrap())
                .collect::<Vec<_>>()
        });
    Bench::new("dispatch/1-coalesced-call")
        .bytes(4 * bytes)
        .run(|| exe_co.index_coalesced(&batch_refs, &keys).unwrap());

    // --- 4: compression on the three content distributions. ---
    group("ablation: compression (row of 262k objects)");
    for (name, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 16 }),
    ] {
        // Build one attribute row by indexing generated batches.
        let cfg = BicConfig { n_records: 256, w_words: 8, m_keys: 16 };
        let mut gen = WorkloadGen::new(cfg, dist, 3);
        let mut core = sotb_bic::bic::BicCore::new(cfg);
        let mut bits = Vec::new();
        for _ in 0..1024 {
            let b = gen.batch_at(0.0);
            let bi = core.index(&b.records, &b.keys);
            for j in 0..256 {
                bits.push(bi.get(0, j));
            }
        }
        let row = Bitmap::from_bools(&bits);
        let wah = WahBitmap::compress(&row);
        let roar = RoaringBitmap::from_bitmap(&row);
        println!(
            "{name}: raw {} B | WAH {} B ({:.2}x) | roaring {} B ({:.2}x) | density {:.3}",
            row.len() / 8,
            wah.compressed_bytes(),
            wah.ratio(),
            roar.compressed_bytes(),
            (row.len() / 8) as f64 / roar.compressed_bytes() as f64,
            row.count_ones() as f64 / row.len() as f64,
        );
        Bench::new(format!("compress/wah-{name}")).run(|| WahBitmap::compress(&row));
        Bench::new(format!("compress/roaring-{name}"))
            .run(|| RoaringBitmap::from_bitmap(&row));
    }
}
