//! Design-choice ablations (DESIGN.md calls these out):
//!   1. compression — WAH vs roaring vs raw vs the adaptive chooser on
//!      the three content distributions, plus compressed-vs-decompress
//!      execution of the AND kernel (runs without artifacts; this is the
//!      measurement behind the codec-selection thresholds in PERF.md);
//!   2. kernel fusion — fused match+pack vs the two-step artifact;
//!   3. hardware formulation — VPU compare-reduce vs MXU one-hot matmul;
//!   4. dispatch coalescing — 4 batches per PJRT call vs 4 calls.
//!
//! The compression section emits `BENCH_compression.json` (row stats,
//! per-codec sizes, chosen codec, and the timed cases) for the CI
//! bench-smoke gate; `BENCH_SMOKE=1` shrinks the corpus and the
//! measurement budget. Ablations 2-4 need the AOT artifacts and are
//! skipped gracefully when the manifest is absent.

use sotb_bic::bic::{
    BicConfig, Bitmap, CompressedIndex, Query, RoaringBitmap, RowStats, WahBitmap,
};
use sotb_bic::coordinator::{ContentDist, WorkloadGen};
use sotb_bic::runtime::{BicExecutable, Manifest, Runtime};
use sotb_bic::substrate::bench::{group, smoke_mode, Bench, BenchResult};
use sotb_bic::substrate::json::Json;
use sotb_bic::substrate::rng::Xoshiro256;

/// A bench under the mode-appropriate measurement budget.
fn bench(name: impl Into<String>) -> Bench {
    Bench::auto(name)
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut dists: Vec<Json> = Vec::new();

    // --- Compression & compressed execution (no artifacts needed). ---
    group("ablation: compression & compressed execution (per distribution)");
    for (name, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 16 }),
    ] {
        let cfg = BicConfig { n_records: 256, w_words: 8, m_keys: 16 };
        let nbatches = if smoke_mode() { 256 } else { 1024 };
        let bi = WorkloadGen::new(cfg, dist, 3).attribute_rows(nbatches);
        let row: &Bitmap = bi.row(0);
        let stats = RowStats::analyze(row);
        let wah = WahBitmap::compress(row);
        let roar = RoaringBitmap::from_bitmap(row);
        let raw_bytes = row.len().div_ceil(8);
        let ci = CompressedIndex::from_index(&bi);
        let h = ci.codec_histogram();
        println!(
            "{name}: raw {} B | WAH {} B ({:.2}x) | roaring {} B ({:.2}x) | \
             density {:.4} | mean run {:.1} b -> chosen {:?}; index ratio {:.2}x \
             (raw/wah/roaring rows {}/{}/{})",
            raw_bytes,
            wah.compressed_bytes(),
            wah.ratio(),
            roar.compressed_bytes(),
            raw_bytes as f64 / roar.compressed_bytes().max(1) as f64,
            stats.density(),
            stats.mean_run_len(),
            stats.choose(),
            ci.ratio(),
            h[0],
            h[1],
            h[2],
        );
        // The compressed planner must agree with the reference before
        // anything here is worth timing.
        let q = Query::attr(0).and(Query::attr(2)).and(Query::attr(4).not());
        assert_eq!(
            q.eval_compressed(&ci).unwrap(),
            q.eval(&bi).unwrap(),
            "{name}: compressed eval diverged"
        );
        results.push(
            bench(format!("compress/wah-{name}"))
                .bytes(raw_bytes as u64)
                .run(|| WahBitmap::compress(row)),
        );
        results.push(
            bench(format!("compress/roaring-{name}"))
                .bytes(raw_bytes as u64)
                .run(|| RoaringBitmap::from_bitmap(row)),
        );
        results.push(
            bench(format!("compress/adaptive-index-{name}"))
                .bytes((raw_bytes * cfg.m_keys) as u64)
                .run(|| CompressedIndex::from_index(&bi)),
        );
        // Compressed execution vs decompress-then-execute on the AND
        // kernel two WAH rows at a time.
        let w0 = WahBitmap::compress(bi.row(0));
        let w1 = WahBitmap::compress(bi.row(1));
        results.push(
            bench(format!("candop/and-compressed-{name}")).run(|| w0.and(&w1)),
        );
        results.push(
            bench(format!("candop/and-via-decompress-{name}"))
                .run(|| w0.decompress().and(&w1.decompress())),
        );
        dists.push(Json::obj([
            ("dist", name.into()),
            ("nbits", row.len().into()),
            ("density", stats.density().into()),
            ("mean_run_len", stats.mean_run_len().into()),
            ("raw_bytes", raw_bytes.into()),
            ("wah_bytes", wah.compressed_bytes().into()),
            ("roaring_bytes", roar.compressed_bytes().into()),
            ("chosen_codec", format!("{:?}", stats.choose()).into()),
            ("index_ratio", ci.ratio().into()),
            (
                "codec_histogram",
                vec![h[0], h[1], h[2]].into(),
            ),
        ]));
    }

    let json = Json::obj([
        ("distributions", Json::Arr(dists)),
        (
            "compression",
            Json::Arr(results.iter().map(BenchResult::to_json).collect()),
        ),
    ]);
    let path = "BENCH_compression.json";
    match std::fs::write(path, json.render() + "\n") {
        Ok(()) => println!("\nwrote {} results to {path}", results.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    // --- PJRT-dependent ablations. ---
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        println!("(pjrt ablations skipped: run `make artifacts` first)");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let rt = Runtime::cpu().expect("PJRT");

    // Fusion & formulation, on the batch geometry.
    group("ablation: kernel fusion & formulation (batch: 256x32, 16 keys)");
    let fused_v = manifest.find_bic("batch").unwrap();
    let twostep_v = manifest.find_twostep("batch").unwrap();
    let mxu_v = manifest.find_mxu("batch").unwrap();
    let mut rng = Xoshiro256::seeded(1);
    let recs: Vec<Vec<i32>> = (0..fused_v.n)
        .map(|_| (0..fused_v.w).map(|_| rng.next_below(256) as i32).collect())
        .collect();
    let keys: Vec<i32> =
        (0..fused_v.m).map(|_| rng.next_below(256) as i32).collect();
    let bytes = (fused_v.n * fused_v.w) as u64;
    for (label, v) in [("fused", fused_v), ("twostep", twostep_v), ("mxu", mxu_v)] {
        let exe = BicExecutable::load(&rt, v).expect("compile");
        // All three must agree before we time them.
        let out = exe.index(&recs, &keys).unwrap();
        let fused_exe = BicExecutable::load(&rt, fused_v).unwrap();
        assert_eq!(out, fused_exe.index(&recs, &keys).unwrap(), "{label}");
        bench(format!("pjrt/{label}"))
            .bytes(bytes)
            .run(|| exe.index(&recs, &keys).unwrap());
    }

    // Dispatch coalescing.
    group("ablation: dispatch coalescing (4 batches)");
    let co_v = manifest.find_coalesce("batch").unwrap();
    let exe_one = BicExecutable::load(&rt, fused_v).unwrap();
    let exe_co = BicExecutable::load(&rt, co_v).unwrap();
    let batches: Vec<Vec<Vec<i32>>> = (0..4)
        .map(|_| {
            (0..co_v.n)
                .map(|_| (0..co_v.w).map(|_| rng.next_below(256) as i32).collect())
                .collect()
        })
        .collect();
    let batch_refs: Vec<&[Vec<i32>]> = batches.iter().map(|b| b.as_slice()).collect();
    bench("dispatch/4-separate-calls")
        .bytes(4 * bytes)
        .run(|| {
            batches
                .iter()
                .map(|b| exe_one.index(b, &keys).unwrap())
                .collect::<Vec<_>>()
        });
    bench("dispatch/1-coalesced-call")
        .bytes(4 * bytes)
        .run(|| exe_co.index_coalesced(&batch_refs, &keys).unwrap());
}
