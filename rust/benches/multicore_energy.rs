//! Bench target for the Fig. 4 system claim: standby-policy ablation on
//! a diurnal trace at full scale (energy proportionality), plus timing
//! of the discrete-event scheduler itself.

use sotb_bic::coordinator::Policy;
use sotb_bic::experiments::multicore::{self, Scale};
use sotb_bic::substrate::bench::{group, Bench};

fn main() {
    group("multicore: standby-policy ablation (full scale)");
    let r = multicore::run(Scale::Full);
    println!("{}", r.render());

    Bench::new("multicore/scheduler-quick-trace").run(|| {
        multicore::run_policy(
            Policy::CgThenRbb { idle_to_cg: 1e-3, cg_to_rbb: 50e-3 },
            Scale::Quick,
        )
    });
}
