//! Bench target for Fig. 7: regenerates the energy-per-cycle table and
//! times the sweep; also attributes one simulated batch's energy across
//! blocks (the activity-weighted split the figure aggregates).

use sotb_bic::bic::BicConfig;
use sotb_bic::experiments::fig7;
use sotb_bic::power::{attribute, delay, Supply};
use sotb_bic::sim::CoreSim;
use sotb_bic::substrate::bench::{group, Bench};
use sotb_bic::substrate::rng::Xoshiro256;
use sotb_bic::substrate::stats::format_si;

fn main() {
    group("fig7: energy per cycle vs Vdd");
    let r = fig7::run();
    println!("{}", r.render());
    Bench::new("fig7/model-sweep").run(fig7::series);

    // Per-block attribution of one chip batch at 1.2 V.
    let mut sim = CoreSim::new(BicConfig::CHIP);
    let mut rng = Xoshiro256::seeded(1);
    let recs: Vec<Vec<i32>> = (0..16)
        .map(|_| (0..32).map(|_| rng.next_below(256) as i32).collect())
        .collect();
    let keys: Vec<i32> = (0..8).map(|_| rng.next_below(256) as i32).collect();
    let run = sim.index_batch(&recs, &keys);
    let s = Supply::new(1.2);
    let br = attribute(s, delay::f_max_chip(s), &run.activity);
    println!(
        "\nper-batch attribution @1.2V: clock={} cam={} buffer={} tm={} ctrl={} leak={} total={}",
        format_si(br.clock_tree, "J"),
        format_si(br.cam, "J"),
        format_si(br.buffer, "J"),
        format_si(br.tm, "J"),
        format_si(br.control, "J"),
        format_si(br.leakage, "J"),
        format_si(br.total(), "J"),
    );
    Bench::new("fig7/cycle-sim+attribution").run(|| {
        let mut sim = CoreSim::new(BicConfig::CHIP);
        let run = sim.index_batch(&recs, &keys);
        attribute(s, delay::f_max_chip(s), &run.activity)
    });
}
