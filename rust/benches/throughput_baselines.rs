//! Bench target for the §I throughput claims: simulated multi-core ASIC
//! system vs published CPU/GPU/FPGA operating points plus a live software
//! indexer, at full scale.

use sotb_bic::experiments::throughput::{self, Scale};
use sotb_bic::substrate::bench::{group, Bench, BenchConfig};

fn main() {
    group("throughput: BIC system vs baselines (full scale)");
    let r = throughput::run(Scale::Full);
    println!("{}", r.render());

    let quick = BenchConfig::default();
    Bench::new("throughput/simulate-8core-200batches")
        .with_config(quick)
        .run(|| throughput::simulate_system(8, Scale::Quick));
    Bench::new("throughput/software-indexer-batch").run(|| {
        throughput::measure_software(Scale::Quick)
    });
}
