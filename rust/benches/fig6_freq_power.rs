//! Bench target for Fig. 6: regenerates the frequency/power-vs-Vdd table
//! and times the underlying model sweep.

use sotb_bic::experiments::fig6;
use sotb_bic::substrate::bench::{group, Bench};

fn main() {
    group("fig6: frequency & active power vs Vdd");
    let r = fig6::run();
    println!("{}", r.render());
    Bench::new("fig6/model-sweep").run(fig6::series);
}
