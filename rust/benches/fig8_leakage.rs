//! Bench target for Fig. 8: regenerates the standby-current grid and
//! times the leakage-model evaluation.

use sotb_bic::experiments::fig8;
use sotb_bic::power::leakage;
use sotb_bic::substrate::bench::{group, Bench};

fn main() {
    group("fig8: standby current vs Vbb x Vdd");
    let r = fig8::run();
    println!("{}", r.render());
    Bench::new("fig8/grid-evaluation").run(leakage::fig8_grid);
}
