//! Bench target for Fig. 5: regenerates the die-features table (memory
//! census + physical-design model) and times the structural census.

use sotb_bic::bic::BicConfig;
use sotb_bic::experiments::fig5;
use sotb_bic::substrate::bench::{group, Bench};

fn main() {
    group("fig5: die features");
    let r = fig5::run();
    println!("{}", r.render());
    Bench::new("fig5/census+physical-model").run(|| fig5::estimate(&BicConfig::CHIP));
    Bench::new("fig5/census-fpga-geometry").run(|| fig5::estimate(&BicConfig::FPGA));
}
