//! Bench target for Table I: regenerates the standby-power-per-bit
//! comparison (all rows recomputed from design characteristics).

use sotb_bic::baselines::table1;
use sotb_bic::experiments::table1 as exp_table1;
use sotb_bic::substrate::bench::{group, Bench};

fn main() {
    group("table1: standby power per bit");
    let r = exp_table1::run();
    println!("{}", r.render());
    Bench::new("table1/recompute-all-rows").run(table1);
}
