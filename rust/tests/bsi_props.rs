//! Bit-sliced tier properties: the slice circuit, the aggregate
//! kernels, and the `BICSEG3` persistence path — all driven through the
//! engine facade and pinned against two independent references.
//!
//! The headline property: with the tier on, every range predicate is
//! **bit-identical** to (a) the O(domain) OR-expansion of a `.bsi(false)`
//! twin engine fed the same batches and (b) a brute-force scan of the
//! raw records, on all three workload content distributions. Aggregates
//! and top-k are pinned against a scalar reference the same way, and
//! both survive flush → reopen → compaction; stores written without
//! sections (the v2 on-disk era) reopen with the tier on and fall back
//! per chunk.
//!
//! Records here carry **one** word each (`w_words: 1`): the column is
//! single-valued per record, so every chunk builds its slices. The
//! multi-valued decline path is what the `.bsi(false)` twin and the v2
//! fallback test exercise — a declined chunk and an absent section take
//! the same structural-evaluation route.

use std::fs;
use std::path::PathBuf;

use sotb_bic::bic::{BicConfig, Bitmap};
use sotb_bic::coordinator::{ContentDist, WorkloadGen};
use sotb_bic::engine::{
    col, AggFn, AggResult, CompactionMode, Engine, EngineBuilder, Predicate,
    Schema,
};

const CFG: BicConfig = BicConfig { n_records: 64, w_words: 1, m_keys: 8 };

/// Column domain `0..200` under workload words drawn from `0..256`:
/// roughly a fifth of the records carry no value at all, so the slices'
/// presence mask and the fallback's absent-object handling are both on
/// the hook in every test.
const DOMAIN: i32 = 200;

const DISTS: [(&str, ContentDist); 3] = [
    ("uniform", ContentDist::Uniform),
    ("zipf", ContentDist::Zipf { s: 1.2 }),
    ("clustered", ContentDist::Clustered { spread: 16 }),
];

fn schema() -> Schema {
    Schema::single("v", 0..DOMAIN).expect("valid schema")
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bic-bsi-props-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn builder(bsi: bool) -> EngineBuilder {
    Engine::builder(schema())
        .batch_records(CFG.n_records)
        .record_words(CFG.w_words)
        .bsi(bsi)
}

fn batches(dist: ContentDist, seed: u64, k: usize) -> Vec<Vec<Vec<i32>>> {
    let mut g = WorkloadGen::new(CFG, dist, seed);
    (0..k).map(|i| g.batch_at(i as f64).records).collect()
}

/// Per-object column value: the record's only word, when in domain.
fn values(data: &[Vec<Vec<i32>>]) -> Vec<Option<i64>> {
    data.iter()
        .flat_map(|b| b.iter())
        .map(|r| (0..DOMAIN).contains(&r[0]).then(|| i64::from(r[0])))
        .collect()
}

/// Brute-force evaluation of a per-object check over the raw values.
fn brute(vals: &[Option<i64>], f: &dyn Fn(Option<i64>) -> bool) -> Bitmap {
    let mut bm = Bitmap::zeros(vals.len());
    for (j, &v) in vals.iter().enumerate() {
        if f(v) {
            bm.set(j, true);
        }
    }
    bm
}

type Check = Box<dyn Fn(Option<i64>) -> bool>;

fn has(f: impl Fn(i64) -> bool + 'static) -> Check {
    Box::new(move |v| v.is_some_and(&f))
}

/// Predicate corpus with matching scalar semantics: every range shape
/// the planner can route to the slice circuit, plus compounds whose
/// Boolean structure wraps range leaves (and a `not`, whose complement
/// must include the objects that carry no value at all).
fn corpus() -> Vec<(&'static str, Predicate, Check)> {
    vec![
        ("ge", col("v").ge(120), has(|v| v >= 120)),
        ("le", col("v").le(77), has(|v| v <= 77)),
        ("gt", col("v").gt(0), has(|v| v > 0)),
        ("lt", col("v").lt(13), has(|v| v < 13)),
        (
            "between",
            col("v").between(64, 191),
            has(|v| (64..=191).contains(&v)),
        ),
        (
            "between-all",
            col("v").between(0, DOMAIN - 1),
            has(|v| (0..i64::from(DOMAIN)).contains(&v)),
        ),
        ("between-point", col("v").between(42, 42), has(|v| v == 42)),
        (
            "range-or",
            col("v").between(20, 60).or(col("v").ge(180)),
            has(|v| (20..=60).contains(&v) || v >= 180),
        ),
        (
            "range-and",
            col("v").ge(100).and(col("v").le(150)),
            has(|v| (100..=150).contains(&v)),
        ),
        (
            "range-not",
            col("v").between(50, 150).not(),
            Box::new(|v| !v.is_some_and(|v| (50..=150).contains(&v))),
        ),
        (
            "in-set",
            col("v").in_set([3, 77, 123]),
            has(|v| [3, 77, 123].contains(&v)),
        ),
    ]
}

/// Scalar aggregate reference over the kept-and-present objects:
/// `(rows, sum, min, max)`.
fn ref_agg(
    vals: &[Option<i64>],
    keep: &dyn Fn(Option<i64>) -> bool,
) -> (u64, i64, Option<i64>, Option<i64>) {
    let picked: Vec<i64> =
        vals.iter().filter(|&&v| keep(v)).filter_map(|&v| v).collect();
    (
        picked.len() as u64,
        picked.iter().sum(),
        picked.iter().min().copied(),
        picked.iter().max().copied(),
    )
}

/// Scalar top-k reference: value descending, object id ascending on
/// ties — the kernels' order contract.
fn ref_top_k(
    vals: &[Option<i64>],
    keep: &dyn Fn(Option<i64>) -> bool,
    k: usize,
) -> Vec<(u64, i64)> {
    let mut out: Vec<(u64, i64)> = vals
        .iter()
        .enumerate()
        .filter(|&(_, &v)| keep(v))
        .filter_map(|(j, &v)| v.map(|x| (j as u64, x)))
        .collect();
    out.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    out.truncate(k);
    out
}

/// Assert all four aggregate functions against the scalar reference.
fn check_aggs(
    engine: &Engine,
    tag: &str,
    filter: Option<&Predicate>,
    vals: &[Option<i64>],
    keep: &dyn Fn(Option<i64>) -> bool,
) {
    let (rows, sum, min, max) = ref_agg(vals, keep);
    for (agg, value) in [
        (AggFn::Count, Some(rows as i64)),
        (AggFn::Sum, Some(sum)),
        (AggFn::Min, min),
        (AggFn::Max, max),
    ] {
        assert_eq!(
            engine.aggregate("v", agg, filter).expect("aggregate"),
            AggResult { rows, value },
            "{tag}: {agg:?}"
        );
    }
}

#[test]
fn slice_circuit_is_bit_identical_to_or_expansion_and_brute_force() {
    for (tag, dist) in DISTS {
        let slice = builder(true).build().expect("build bsi engine");
        let orexp = builder(false).build().expect("build or-expansion twin");
        let data = batches(dist, 0xB510 + tag.len() as u64, 6);
        slice.ingest_batches(&data).expect("ingest slice");
        orexp.ingest_batches(&data).expect("ingest orexp");
        let vals = values(&data);

        for (name, p, f) in corpus() {
            let want = brute(&vals, &*f);
            assert_eq!(
                slice.select(&p).expect("slice select"),
                want,
                "{tag}: {name} slice circuit diverged from brute force"
            );
            assert_eq!(
                orexp.select(&p).expect("or-expansion select"),
                want,
                "{tag}: {name} or-expansion diverged from brute force"
            );
        }

        // The identity above must actually compare the two tiers: the
        // bsi engine routed ranges through the circuit, the twin never
        // could (no layout — every range is expanded rows).
        assert!(
            slice.stats().queries_bsi > 0,
            "{tag}: planner never took the bit-sliced tier"
        );
        assert_eq!(
            slice
                .explain(&col("v").between(10, 90), false)
                .expect("explain")
                .tier,
            "bsi",
            "{tag}: explain did not choose the bit-sliced tier"
        );
        assert_eq!(
            orexp.stats().queries_bsi,
            0,
            "{tag}: the bsi-off twin took the bit-sliced tier"
        );
    }
}

#[test]
fn aggregates_and_top_k_match_scalar_reference() {
    for (tag, dist) in DISTS {
        let slice = builder(true).build().expect("build bsi engine");
        let orexp = builder(false).build().expect("build fallback twin");
        let data = batches(dist, 0xA660 + tag.len() as u64, 6);
        slice.ingest_batches(&data).expect("ingest slice");
        orexp.ingest_batches(&data).expect("ingest orexp");
        let vals = values(&data);

        let filters: Vec<(&str, Option<Predicate>, Check)> = vec![
            ("unfiltered", None, Box::new(|_| true)),
            (
                "between",
                Some(col("v").between(30, 160)),
                has(|v| (30..=160).contains(&v)),
            ),
            ("narrow", Some(col("v").ge(190)), has(|v| v >= 190)),
            (
                // A negated filter admits objects with no value; the
                // kernels must still count only carriers.
                "negated",
                Some(col("v").between(50, 150).not()),
                Box::new(|v| !v.is_some_and(|v| (50..=150).contains(&v))),
            ),
        ];
        for (fname, filter, keep) in &filters {
            let label = format!("{tag}/{fname} (sliced)");
            check_aggs(&slice, &label, filter.as_ref(), &vals, &**keep);
            let label = format!("{tag}/{fname} (fallback)");
            check_aggs(&orexp, &label, filter.as_ref(), &vals, &**keep);
            for k in [0, 1, 5, 1000] {
                let want = ref_top_k(&vals, &**keep, k);
                assert_eq!(
                    slice.top_k("v", k, filter.as_ref()).expect("topk"),
                    want,
                    "{tag}/{fname}: sliced top-{k}"
                );
                assert_eq!(
                    orexp.top_k("v", k, filter.as_ref()).expect("topk"),
                    want,
                    "{tag}/{fname}: fallback top-{k}"
                );
            }
        }
        assert!(slice.stats().aggregates > 0, "{tag}: no aggregates counted");
        assert!(
            slice.stats().topk_queries > 0,
            "{tag}: no top-k queries counted"
        );
    }
}

#[test]
fn sectionless_store_reopens_with_tier_on_and_falls_back() {
    let dir = tmpdir("v2-fallback");
    let data = batches(ContentDist::Uniform, 0xF0F0, 4);
    {
        // Write the store with the tier off: every segment lands on
        // disk without a `BICSEG3` section, exactly like a v2-era file.
        let old = builder(false)
            .durable(&dir)
            .flush_batches(1)
            .build()
            .expect("build bsi-off writer");
        old.ingest_batches(&data).expect("ingest");
        old.close().expect("close writer");
    }

    // Reopen with the tier on: the planner still routes ranges to the
    // bit-sliced tier, and every sectionless chunk answers through the
    // structural fallback — same bits, no section required.
    let engine = builder(true)
        .durable(&dir)
        .flush_batches(1)
        .build()
        .expect("reopen with bsi on");
    let mut vals = values(&data);
    for (name, p, f) in corpus() {
        assert_eq!(
            engine.select(&p).expect("select"),
            brute(&vals, &*f),
            "sectionless fallback: {name}"
        );
    }
    assert_eq!(
        engine
            .explain(&col("v").between(10, 90), false)
            .expect("explain")
            .tier,
        "bsi",
        "reopened store: explain did not choose the bit-sliced tier"
    );
    assert!(
        engine.stats().queries_bsi > 0,
        "reopened store: planner never took the bit-sliced tier"
    );

    // New batches flush with sections; the mixed store (sectionless
    // old segments + sliced new ones) stays pinned to the references.
    let more = batches(ContentDist::Zipf { s: 1.2 }, 0xF0F1, 2);
    engine.ingest_batches(&more).expect("ingest more");
    vals.extend(values(&more));
    for (name, p, f) in corpus() {
        assert_eq!(
            engine.select(&p).expect("select"),
            brute(&vals, &*f),
            "mixed store: {name}"
        );
    }
    check_aggs(&engine, "mixed store", None, &vals, &|_| true);
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn slices_survive_flush_reopen_and_compaction() {
    let dir = tmpdir("compact");
    let data = batches(ContentDist::Clustered { spread: 16 }, 0xC0DE, 8);
    let vals = values(&data);
    {
        let engine = builder(true)
            .durable(&dir)
            .flush_batches(1) // every batch becomes a segment...
            .max_segments(2) // ...so compaction merges along the way
            .compaction(CompactionMode::Foreground)
            .build()
            .expect("build");
        engine.ingest_batches(&data).expect("ingest");
        let stats = engine.close().expect("close");
        assert!(stats.compaction_rounds > 0, "compaction never ran");
    }

    // Everything below is answered from recovered segments whose
    // sections round-tripped through flush and compaction merges.
    let engine = builder(true)
        .durable(&dir)
        .flush_batches(1)
        .max_segments(2)
        .compaction(CompactionMode::Foreground)
        .build()
        .expect("reopen");
    for (name, p, f) in corpus() {
        assert_eq!(
            engine.select(&p).expect("select"),
            brute(&vals, &*f),
            "after compaction + reopen: {name}"
        );
    }
    check_aggs(&engine, "after compaction + reopen", None, &vals, &|_| true);
    let filter = col("v").between(40, 180);
    let keep: Check = has(|v| (40..=180).contains(&v));
    for k in [1, 7, 64] {
        assert_eq!(
            engine.top_k("v", k, Some(&filter)).expect("topk"),
            ref_top_k(&vals, &*keep, k),
            "after compaction + reopen: top-{k}"
        );
    }
    assert!(
        engine.stats().queries_bsi > 0,
        "recovered store: planner never took the bit-sliced tier"
    );
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}
