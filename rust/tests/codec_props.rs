//! Property tests for the compressed-execution tier: WAH/roaring/raw
//! round-trip exactness, bit-identity of every compressed kernel
//! (AND/OR/NOT/AND-NOT, same-codec and cross-codec, plus the
//! into-accumulator forms) against the uncompressed reference, and
//! differential equivalence of the selectivity-ordered compressed query
//! planner — all over ragged tails (`n % 31 != 0`, `n % 64 != 0`),
//! word-aligned sizes, and empty rows, and over indexes built from the
//! three workload content distributions.

use sotb_bic::bic::{
    BicConfig, Bitmap, BitmapIndex, Codec, CodecBitmap, CompressedIndex, Query,
};
use sotb_bic::coordinator::{ContentDist, WorkloadGen};
use sotb_bic::substrate::proptest::{check, Gen};

fn arb_bitmap(g: &mut Gen, nbits: usize) -> Bitmap {
    // Mix shapes: scattered-random, runny, and near-constant rows, so
    // every codec sees both its best and worst case.
    match g.usize_in(0, 2) {
        0 => {
            let density = g.f64_in(0.0, 1.0);
            Bitmap::from_bools(&(0..nbits).map(|_| g.chance(density)).collect::<Vec<_>>())
        }
        1 => {
            let mut bits = Vec::with_capacity(nbits);
            let mut v = g.bool();
            while bits.len() < nbits {
                let len = (g.size(200) + 1).min(nbits - bits.len());
                bits.extend(std::iter::repeat(v).take(len));
                v = !v;
            }
            Bitmap::from_bools(&bits)
        }
        _ => {
            if g.bool() {
                Bitmap::zeros(nbits)
            } else {
                Bitmap::ones(nbits)
            }
        }
    }
}

/// Sizes biased onto the codec word boundaries: ragged and exact
/// multiples of the 31-bit WAH group and the 64-bit host word, plus 0.
fn arb_len(g: &mut Gen) -> usize {
    let base = g.size(1_800);
    match g.usize_in(0, 3) {
        0 => base,
        1 => (base / 31) * 31,
        2 => (base / 64) * 64,
        _ => base + 1,
    }
}

fn arb_codec(g: &mut Gen) -> Codec {
    Codec::ALL[g.usize_in(0, 2)]
}

#[test]
fn codec_roundtrip_exact_arbitrary() {
    check("codec-roundtrip", 0xE0, 250, |g| {
        let n = arb_len(g);
        let a = arb_bitmap(g, n);
        for codec in Codec::ALL {
            let cb = CodecBitmap::from_bitmap_as(codec, &a);
            if cb.to_bitmap() != a {
                return Err(format!("{codec:?} roundtrip failed at n={n}"));
            }
            if cb.count_ones() != a.count_ones() {
                return Err(format!("{codec:?} count_ones mismatch at n={n}"));
            }
            if cb.len() != n {
                return Err(format!("{codec:?} len mismatch at n={n}"));
            }
        }
        // The adaptive choice must also round-trip exactly.
        let cb = CodecBitmap::from_bitmap(&a);
        if cb.to_bitmap() != a {
            return Err(format!("adaptive ({:?}) roundtrip failed at n={n}", cb.codec()));
        }
        Ok(())
    });
}

#[test]
fn codec_kernels_bit_identical_arbitrary() {
    check("codec-kernels", 0xE1, 150, |g| {
        let n = arb_len(g);
        let a = arb_bitmap(g, n);
        let b = arb_bitmap(g, n);
        let (ca, cb) = (arb_codec(g), arb_codec(g));
        let x = CodecBitmap::from_bitmap_as(ca, &a);
        let y = CodecBitmap::from_bitmap_as(cb, &b);
        let ctx = format!("{ca:?}x{cb:?} n={n}");
        if x.and(&y).to_bitmap() != a.and(&b) {
            return Err(format!("AND diverged ({ctx})"));
        }
        if x.or(&y).to_bitmap() != a.or(&b) {
            return Err(format!("OR diverged ({ctx})"));
        }
        if x.and_not(&y).to_bitmap() != a.and_not(&b) {
            return Err(format!("ANDNOT diverged ({ctx})"));
        }
        if x.not().to_bitmap() != a.not() {
            return Err(format!("NOT diverged ({ctx})"));
        }
        let mut acc = a.clone();
        y.and_into(&mut acc);
        if acc != a.and(&b) {
            return Err(format!("and_into diverged ({ctx})"));
        }
        let mut acc = a.clone();
        y.and_not_into(&mut acc);
        if acc != a.and_not(&b) {
            return Err(format!("and_not_into diverged ({ctx})"));
        }
        let mut acc = a.clone();
        y.or_into(&mut acc);
        if acc != a.or(&b) {
            return Err(format!("or_into diverged ({ctx})"));
        }
        Ok(())
    });
}

fn arb_query(g: &mut Gen, m: usize, depth: usize) -> Query {
    if depth == 0 || g.chance(0.4) {
        return Query::Attr(g.usize_in(0, m - 1));
    }
    match g.usize_in(0, 2) {
        0 => Query::And((0..g.usize_in(0, 3)).map(|_| arb_query(g, m, depth - 1)).collect()),
        1 => Query::Or((0..g.usize_in(0, 3)).map(|_| arb_query(g, m, depth - 1)).collect()),
        _ => Query::Not(Box::new(arb_query(g, m, depth - 1))),
    }
}

#[test]
fn compressed_planner_matches_reference_on_arbitrary_indexes() {
    check("compressed-planner", 0xE2, 80, |g| {
        let m = g.usize_in(1, 6);
        let n = arb_len(g).max(1);
        let rows: Vec<Bitmap> = (0..m).map(|_| arb_bitmap(g, n)).collect();
        let bi = BitmapIndex::from_rows(rows);
        let q = arb_query(g, m, 3);
        let expect = q.eval(&bi).map_err(|e| e.to_string())?;
        let adaptive = CompressedIndex::from_index(&bi);
        if q.eval_compressed(&adaptive).map_err(|e| e.to_string())? != expect {
            return Err(format!("adaptive planner diverged (m={m} n={n}): {q:?}"));
        }
        for codec in Codec::ALL {
            let ci = CompressedIndex::from_index_forced(&bi, codec);
            if q.eval_compressed(&ci).map_err(|e| e.to_string())? != expect {
                return Err(format!("{codec:?} planner diverged (m={m} n={n}): {q:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn and_chain_order_never_changes_the_result() {
    check("planner-order-invariance", 0xE3, 80, |g| {
        let m = g.usize_in(2, 6);
        let n = arb_len(g).max(1);
        let rows: Vec<Bitmap> = (0..m).map(|_| arb_bitmap(g, n)).collect();
        let bi = BitmapIndex::from_rows(rows);
        let ci = CompressedIndex::from_index(&bi);
        let mut ops: Vec<Query> = (0..m)
            .map(|i| {
                if g.bool() {
                    Query::Attr(i)
                } else {
                    Query::Attr(i).not()
                }
            })
            .collect();
        let expect = Query::And(ops.clone()).eval(&bi).map_err(|e| e.to_string())?;
        for _ in 0..3 {
            g.rng().shuffle(&mut ops);
            let got = Query::And(ops.clone())
                .eval_compressed(&ci)
                .map_err(|e| e.to_string())?;
            if got != expect {
                return Err(format!("shuffle changed the result (m={m} n={n})"));
            }
        }
        Ok(())
    });
}

/// The acceptance differential: compressed execution is bit-identical to
/// the uncompressed `Query` path on indexes built from all three content
/// distributions (Uniform, Zipf, Clustered).
#[test]
fn compressed_query_differential_across_workloads() {
    for (name, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 12 }),
    ] {
        let cfg = BicConfig { n_records: 64, w_words: 8, m_keys: 8 };
        let bi = WorkloadGen::new(cfg, dist, 0x5EED).attribute_rows(96);
        let adaptive = CompressedIndex::from_index(&bi);
        let forced: Vec<CompressedIndex> = Codec::ALL
            .iter()
            .map(|&c| CompressedIndex::from_index_forced(&bi, c))
            .collect();
        check(&format!("workload-differential-{name}"), 0xE4, 40, |g| {
            let q = arb_query(g, cfg.m_keys, 3);
            let expect = q.eval(&bi).map_err(|e| e.to_string())?;
            if q.eval_compressed(&adaptive).map_err(|e| e.to_string())? != expect {
                return Err(format!("{name}: adaptive diverged on {q:?}"));
            }
            for (c, ci) in Codec::ALL.iter().zip(&forced) {
                if q.eval_compressed(ci).map_err(|e| e.to_string())? != expect {
                    return Err(format!("{name}: {c:?} diverged on {q:?}"));
                }
            }
            Ok(())
        });
    }
}
