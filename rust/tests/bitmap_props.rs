//! Property tests: bitmap algebra laws, WAH round-trips, transpose
//! involution — the invariants the query engine's correctness rests on —
//! plus differential pins of the word-parallel kernels (`and_all`, the
//! 64x64 block transpose, the packed u32 interchange) to their retained
//! scalar reference paths, across ragged tail widths and empty bitmaps.

use sotb_bic::bic::bitmap::{packed_words_for, Bitmap, BitmapIndex};
use sotb_bic::bic::transpose::{pack_rows, transpose, transpose_packed, untranspose};
use sotb_bic::bic::WahBitmap;
use sotb_bic::substrate::proptest::{check, Gen};

fn arb_bitmap(g: &mut Gen, nbits: usize) -> Bitmap {
    let density = g.f64_in(0.0, 1.0);
    let bits: Vec<bool> = (0..nbits).map(|_| g.chance(density)).collect();
    Bitmap::from_bools(&bits)
}

#[test]
fn de_morgan_laws() {
    check("de-morgan", 0xD0, 200, |g| {
        let n = g.size(300) + 1;
        let a = arb_bitmap(g, n);
        let b = arb_bitmap(g, n);
        if a.and(&b).not() != a.not().or(&b.not()) {
            return Err("!(a&b) != !a | !b".into());
        }
        if a.or(&b).not() != a.not().and(&b.not()) {
            return Err("!(a|b) != !a & !b".into());
        }
        Ok(())
    });
}

#[test]
fn involution_and_identities() {
    check("involution", 0xD1, 200, |g| {
        let n = g.size(300) + 1;
        let a = arb_bitmap(g, n);
        if a.not().not() != a {
            return Err("!!a != a".into());
        }
        if a.and(&Bitmap::ones(n)) != a || a.or(&Bitmap::zeros(n)) != a {
            return Err("identity elements violated".into());
        }
        if a.xor(&a).count_ones() != 0 {
            return Err("a^a != 0".into());
        }
        if a.and_not(&a).count_ones() != 0 {
            return Err("a&!a != 0".into());
        }
        Ok(())
    });
}

#[test]
fn xor_is_or_minus_and() {
    check("xor-decomposition", 0xD2, 200, |g| {
        let n = g.size(300) + 1;
        let a = arb_bitmap(g, n);
        let b = arb_bitmap(g, n);
        let lhs = a.xor(&b);
        let rhs = a.or(&b).and_not(&a.and(&b));
        if lhs != rhs {
            return Err("a^b != (a|b) & !(a&b)".into());
        }
        Ok(())
    });
}

#[test]
fn count_ones_matches_iteration_and_popcount_sum() {
    check("count-consistency", 0xD3, 150, |g| {
        let n = g.size(500) + 1;
        let a = arb_bitmap(g, n);
        let by_iter = a.iter_ones().count();
        let by_get = (0..n).filter(|&i| a.get(i)).count();
        if a.count_ones() != by_iter || by_iter != by_get {
            return Err(format!(
                "count {} vs iter {} vs get {}",
                a.count_ones(),
                by_iter,
                by_get
            ));
        }
        Ok(())
    });
}

#[test]
fn inplace_ops_equal_functional() {
    check("inplace-vs-functional", 0xD4, 150, |g| {
        let n = g.size(300) + 1;
        let a = arb_bitmap(g, n);
        let b = arb_bitmap(g, n);
        let mut x = a.clone();
        x.and_assign(&b);
        if x != a.and(&b) {
            return Err("and_assign".into());
        }
        let mut x = a.clone();
        x.or_assign(&b);
        if x != a.or(&b) {
            return Err("or_assign".into());
        }
        let mut x = a.clone();
        x.and_not_assign(&b);
        if x != a.and_not(&b) {
            return Err("and_not_assign".into());
        }
        Ok(())
    });
}

#[test]
fn wah_roundtrip_arbitrary() {
    check("wah-roundtrip", 0xD5, 200, |g| {
        let n = g.size(2_000);
        let a = arb_bitmap(g, n);
        let w = WahBitmap::compress(&a);
        if w.decompress() != a {
            return Err(format!("roundtrip failed at n={n}"));
        }
        if w.count_ones() != a.count_ones() {
            return Err("compressed count_ones mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn wah_compressed_ops_match_plain() {
    check("wah-ops", 0xD6, 100, |g| {
        let n = g.size(1_500) + 1;
        let a = arb_bitmap(g, n);
        let b = arb_bitmap(g, n);
        let (wa, wb) = (WahBitmap::compress(&a), WahBitmap::compress(&b));
        if wa.and(&wb).decompress() != a.and(&b) {
            return Err("compressed AND".into());
        }
        if wa.or(&wb).decompress() != a.or(&b) {
            return Err("compressed OR".into());
        }
        Ok(())
    });
}

#[test]
fn wah_runs_compress_well() {
    check("wah-runs", 0xD7, 50, |g| {
        // Runny bitmaps (long fills) must compress below 1/3 of raw:
        // each run costs at most one fill word + one boundary literal,
        // so with runs >= 300 bits the 3x bound always has slack.
        let runs = g.size(20) + 2;
        let mut bits = Vec::new();
        for _ in 0..runs {
            let len = g.size(400) + 300;
            let v = g.bool();
            bits.extend(std::iter::repeat(v).take(len));
        }
        let a = Bitmap::from_bools(&bits);
        let w = WahBitmap::compress(&a);
        if w.compressed_bytes() * 3 > w.uncompressed_bytes() {
            return Err(format!(
                "poor run compression: {}/{} bytes",
                w.compressed_bytes(),
                w.uncompressed_bytes()
            ));
        }
        Ok(())
    });
}

#[test]
fn and_all_matches_pairwise_chain_arbitrary() {
    check("and-all-fused", 0xDA, 200, |g| {
        // Lengths biased around the 512-bit cache-block boundary so the
        // block-skip path and the remainder tail both get exercised.
        let n = g.size(1_200) + 1;
        let k = g.usize_in(0, 5);
        let first = arb_bitmap(g, n);
        let others: Vec<Bitmap> = (0..k).map(|_| arb_bitmap(g, n)).collect();
        let refs: Vec<&Bitmap> = others.iter().collect();
        let fused = first.and_all(&refs);
        let mut chained = first.clone();
        for o in &others {
            chained.and_assign(o);
        }
        if fused != chained {
            return Err(format!("and_all != chained ANDs at n={n} k={k}"));
        }
        Ok(())
    });
}

#[test]
fn packed_u32_interchange_roundtrip_arbitrary() {
    check("u32-interchange", 0xDB, 200, |g| {
        // Includes ragged tails (n % 64 != 0, n % 32 != 0) and n = 0.
        let n = g.size(400);
        let a = arb_bitmap(g, n);
        let packed = a.to_packed_words();
        if packed.len() != packed_words_for(n) {
            return Err(format!("packed length {} at n={n}", packed.len()));
        }
        // Every bit must sit at the contract position: word i/32, bit i%32.
        for i in 0..n {
            let via_packed = (packed[i / 32] >> (i % 32)) & 1 == 1;
            if via_packed != a.get(i) {
                return Err(format!("bit {i} misplaced at n={n}"));
            }
        }
        if Bitmap::from_packed_words(n, &packed) != a {
            return Err(format!("roundtrip failed at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn block_transpose_matches_scalar_arbitrary() {
    check("block-transpose", 0xDC, 150, |g| {
        // Both axes straddle the 64-bit tile boundary, incl. ragged tails.
        let n = g.size(150) + 1;
        let m = g.size(150) + 1;
        let bits: Vec<bool> = (0..n * m).map(|_| g.bool()).collect();
        let scalar = transpose(&bits, n, m);
        let fast = transpose_packed(&pack_rows(&bits, n, m), n, m);
        if fast != scalar {
            return Err(format!("packed transpose diverged at n={n} m={m}"));
        }
        // And the interchange words must agree too (layout, not just Eq).
        if fast.to_packed() != scalar.to_packed() {
            return Err(format!("packed words diverged at n={n} m={m}"));
        }
        Ok(())
    });
}

#[test]
fn transpose_involution_arbitrary() {
    check("transpose-involution", 0xD8, 150, |g| {
        let n = g.size(40) + 1;
        let m = g.size(30) + 1;
        let bits: Vec<bool> = (0..n * m).map(|_| g.bool()).collect();
        let bi = transpose(&bits, n, m);
        if untranspose(&bi) != bits {
            return Err(format!("involution failed at n={n} m={m}"));
        }
        Ok(())
    });
}

#[test]
fn packed_roundtrip_arbitrary() {
    check("packed-roundtrip", 0xD9, 150, |g| {
        let m = g.size(16) + 1;
        let n = g.size(200) + 1;
        let mut bi = BitmapIndex::new(m, n);
        for _ in 0..g.size(64) {
            bi.set(g.usize_in(0, m - 1), g.usize_in(0, n - 1), true);
        }
        let packed = bi.to_packed();
        if BitmapIndex::from_packed(m, n, &packed) != bi {
            return Err("packed roundtrip".into());
        }
        Ok(())
    });
}
