//! Durable-store properties: segment round-trip bit-identity across the
//! three workload content distributions (ragged tails and empty rows
//! included), WAL torn-write recovery at **every byte offset**, the
//! crash windows around flush (torn segment temp file, committed segment
//! without manifest, committed manifest with a stale WAL), compaction
//! equivalence + tombstoning, and query equivalence of the store reader
//! against `Query::eval` over the equivalent uncompressed index.
//!
//! The fault-injection half (seeded, reproducible — see
//! `store::vfs::FaultVfs`): damaged committed segments as typed
//! outcomes under both degraded policies, scrubber quarantine, rename/
//! ENOSPC faults at every operation of a flush, and the chaos crux — a
//! crash-point sweep over every VFS operation of a full engine
//! workload, recovering to exactly the acked batch prefix with all four
//! query execution tiers bit-identical. Failures print `CHAOS_SEED=<n>`;
//! re-running with that env var replays the identical fault.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use sotb_bic::bic::{
    BicConfig, BicCore, Bitmap, BitmapIndex, CompressedIndex, Query,
};
use sotb_bic::coordinator::{ContentDist, ShardedIndexer, WorkloadGen};
use sotb_bic::store::vfs::{FaultKind, FaultSpec, FaultVfs};
use sotb_bic::store::{DegradedPolicy, Store, StoreConfig, StoreError};

/// Small, ragged geometry: 24-bit batch rows (not a multiple of 64, 32,
/// or 31), 6 attributes.
const CFG: BicConfig = BicConfig { n_records: 24, w_words: 8, m_keys: 6 };

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("bic-store-props-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn copy_dir(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for entry in fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

/// The first `k` batches of (cfg, dist, seed), encoded per batch — what
/// gets appended to the store.
fn encoded_batches(
    dist: ContentDist,
    seed: u64,
    k: usize,
) -> Vec<CompressedIndex> {
    let mut g = WorkloadGen::new(CFG, dist, seed);
    let mut core = BicCore::new(CFG);
    (0..k)
        .map(|i| {
            let b = g.batch_at(i as f64);
            CompressedIndex::from_index(&core.index(&b.records, &b.keys))
        })
        .collect()
}

/// The in-memory reference: the same `k` batches concatenated into one
/// uncompressed index (object `b*n_records + j` = batch `b`'s bit `j`).
fn reference(dist: ContentDist, seed: u64, k: usize) -> BitmapIndex {
    WorkloadGen::new(CFG, dist, seed).attribute_rows(k)
}

fn no_autoflush() -> StoreConfig {
    StoreConfig { flush_batches: 0, ..StoreConfig::default() }
}

fn query_corpus() -> Vec<Query> {
    vec![
        Query::attr(1).and(Query::attr(3)).and(Query::attr(4).not()),
        Query::attr(0).or(Query::attr(2).not()),
        Query::And(vec![]),
        Query::Or(vec![]),
        Query::attr(5).not().not(),
        Query::attr(0)
            .and(Query::attr(1).or(Query::attr(2)))
            .and(Query::attr(3).not()),
        Query::Or(vec![
            Query::attr(4),
            Query::And(vec![Query::attr(0), Query::attr(5)]),
        ]),
    ]
}

/// Assert the store's reader is bit-identical to `expect` — full index
/// and the whole query corpus.
fn assert_store_matches(store: &Store, expect: &BitmapIndex, ctx: &str) {
    let reader = store.reader();
    assert_eq!(reader.num_objects(), expect.num_objects(), "{ctx}: objects");
    assert_eq!(&reader.to_index(), expect, "{ctx}: full index");
    for (qi, q) in query_corpus().iter().enumerate() {
        // Queries referencing attributes past a narrow store must error
        // identically on both paths; in-range queries must match bitwise.
        match q.eval(expect) {
            Ok(e) => {
                assert_eq!(reader.eval(q).unwrap(), e, "{ctx}: query {qi}");
                // The segment-by-segment AND/ANDNOT fold must stay
                // bit-identical to the assemble-then-AND reference path.
                assert_eq!(
                    reader.eval_assembled(q).unwrap(),
                    e,
                    "{ctx}: query {qi} assembled reference"
                );
            }
            Err(e) => {
                assert_eq!(
                    reader.eval(q).unwrap_err(),
                    e,
                    "{ctx}: query {qi} error"
                );
                assert_eq!(
                    reader.eval_assembled(q).unwrap_err(),
                    e,
                    "{ctx}: query {qi} assembled error"
                );
            }
        }
    }
}

#[test]
fn ingest_flush_recover_roundtrip_across_distributions() {
    for (tag, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 8 }),
    ] {
        let dir = tmpdir(&format!("dist-{tag}"));
        let k = 9;
        let seed = 0xD15 + tag.len() as u64;
        let cfg = StoreConfig { flush_batches: 4, ..StoreConfig::default() };
        let mut store = Store::create(&dir, CFG.m_keys, cfg.clone()).unwrap();
        for ci in &encoded_batches(dist, seed, k) {
            store.append_batch(ci).unwrap();
        }
        // 9 batches, flush every 4: 2 segments + 1 memtable batch.
        assert_eq!(store.num_segments(), 2, "{tag}");
        assert_eq!(store.memtable_batches(), 1, "{tag}");
        assert!(store.segment_bytes_written() > 0, "{tag}");
        let expect = reference(dist, seed, k);
        assert_store_matches(&store, &expect, tag);
        // Reopen (recovery path) — memtable comes back from the WAL.
        drop(store);
        let store = Store::open(&dir, cfg).unwrap();
        assert_eq!(store.num_segments(), 2, "{tag} reopened");
        assert_eq!(store.memtable_batches(), 1, "{tag} reopened");
        assert_store_matches(&store, &expect, &format!("{tag} reopened"));
        let _ = fs::remove_dir_all(&dir);
    }
}

#[test]
fn empty_rows_and_empty_store_roundtrip() {
    let dir = tmpdir("empty");
    let mut store = Store::create(&dir, 3, no_autoflush()).unwrap();
    assert_eq!(store.num_objects(), 0);
    assert_store_matches(&store, &BitmapIndex::new(3, 0), "fresh");
    // Batches whose rows never match (all-empty rows) still round-trip.
    let empty = CompressedIndex::from_index(&BitmapIndex::new(3, 100));
    store.append_batch(&empty).unwrap();
    store.append_batch(&empty).unwrap();
    store.flush().unwrap();
    assert_store_matches(&store, &BitmapIndex::new(3, 200), "empty rows");
    drop(store);
    let store = Store::open(&dir, no_autoflush()).unwrap();
    assert_store_matches(&store, &BitmapIndex::new(3, 200), "reopened");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn append_rejects_mismatched_batches() {
    let dir = tmpdir("reject");
    let mut store = Store::create(&dir, 3, no_autoflush()).unwrap();
    let wrong_attrs = CompressedIndex::from_index(&BitmapIndex::new(4, 10));
    assert!(store.append_batch(&wrong_attrs).is_err());
    assert!(Store::create(&dir, 3, no_autoflush()).is_err(), "create twice");
    assert_eq!(store.num_objects(), 0, "failed appends left no trace");
    let _ = fs::remove_dir_all(&dir);
}

/// The acceptance crux: truncate the WAL at every byte offset; recovery
/// must yield a queryable index bit-identical to the reference built
/// from the surviving whole-record (= durably acknowledged) prefix.
#[test]
fn wal_torn_write_recovery_at_every_byte_offset() {
    let dist = ContentDist::Clustered { spread: 8 };
    let seed = 0x7042;
    let k = 3;
    let dir = tmpdir("torn-src");
    let mut store = Store::create(&dir, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &encoded_batches(dist, seed, k) {
        store.append_batch(ci).unwrap();
    }
    drop(store);

    // Locate the WAL and its record boundaries.
    let wal_path = dir.join("wal-00000000.log");
    let wal = fs::read(&wal_path).unwrap();
    let mut boundaries = vec![0usize];
    {
        let mut p = 0usize;
        while p < wal.len() {
            let len = u32::from_le_bytes([
                wal[p],
                wal[p + 1],
                wal[p + 2],
                wal[p + 3],
            ]) as usize;
            p += 8 + len;
            boundaries.push(p);
        }
    }
    assert_eq!(boundaries.len(), k + 1, "one boundary per record");

    let refs: Vec<BitmapIndex> =
        (0..=k).map(|r| reference(dist, seed, r)).collect();
    let work = tmpdir("torn-work");
    for cut in 0..=wal.len() {
        let _ = fs::remove_dir_all(&work);
        copy_dir(&dir, &work);
        fs::write(work.join("wal-00000000.log"), &wal[..cut]).unwrap();
        let store = Store::recover(&work, no_autoflush()).unwrap();
        let survived =
            boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        assert_eq!(store.memtable_batches(), survived, "cut at {cut}");
        assert_eq!(
            &store.reader().to_index(),
            &refs[survived],
            "cut at {cut}: prefix-consistent bit identity"
        );
    }
    // A few spot-checks that the recovered store also *queries* right.
    for cut in [0, wal.len() / 2, wal.len()] {
        let _ = fs::remove_dir_all(&work);
        copy_dir(&dir, &work);
        fs::write(work.join("wal-00000000.log"), &wal[..cut]).unwrap();
        let store = Store::recover(&work, no_autoflush()).unwrap();
        let survived =
            boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        assert_store_matches(&store, &refs[survived], &format!("cut {cut}"));
    }
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&work);
}

/// Recovered stores must keep accepting appends (the truncated WAL is
/// resumed, not abandoned).
#[test]
fn recovery_resumes_ingest_after_torn_tail() {
    let dist = ContentDist::Uniform;
    let seed = 0xAB5;
    let dir = tmpdir("resume");
    let batches = encoded_batches(dist, seed, 4);
    let mut store = Store::create(&dir, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &batches[..3] {
        store.append_batch(ci).unwrap();
    }
    drop(store);
    // Tear the last record mid-payload: batch 2 is lost.
    let wal_path = dir.join("wal-00000000.log");
    let wal = fs::read(&wal_path).unwrap();
    fs::write(&wal_path, &wal[..wal.len() - 3]).unwrap();
    let mut store = Store::recover(&dir, no_autoflush()).unwrap();
    assert_eq!(store.memtable_batches(), 2);
    // Re-append batch 2 and batch 3, then flush: the store must equal
    // the 4-batch reference.
    store.append_batch(&batches[2]).unwrap();
    store.append_batch(&batches[3]).unwrap();
    store.flush().unwrap();
    assert_store_matches(&store, &reference(dist, seed, 4), "resumed");
    let _ = fs::remove_dir_all(&dir);
}

/// Crash windows around `flush`, simulated by construction:
/// (a) torn segment temp file, no manifest change;
/// (b) segment fully written but the manifest commit never happened;
/// (c) manifest committed but the old WAL generation never deleted.
/// All three must recover to a consistent view.
#[test]
fn flush_crash_windows_recover_consistently() {
    let dist = ContentDist::Zipf { s: 1.1 };
    let seed = 0xF1A5;
    let k = 5;
    let pre = tmpdir("window-pre");
    let mut store = Store::create(&pre, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &encoded_batches(dist, seed, k) {
        store.append_batch(ci).unwrap();
    }
    drop(store);
    // `post` = the same store after a clean flush.
    let post = tmpdir("window-post");
    copy_dir(&pre, &post);
    let mut store = Store::open(&post, no_autoflush()).unwrap();
    store.flush().unwrap().expect("memtable was non-empty");
    drop(store);
    let expect = reference(dist, seed, k);

    // (a) torn segment temp file next to an unflushed WAL.
    let work = tmpdir("window-a");
    copy_dir(&pre, &work);
    fs::write(work.join("seg-00000000.bic.tmp"), b"torn segment bytes")
        .unwrap();
    let store = Store::recover(&work, no_autoflush()).unwrap();
    assert_eq!(store.num_segments(), 0, "tmp never became live");
    assert_store_matches(&store, &expect, "window a");
    assert!(!work.join("seg-00000000.bic.tmp").exists(), "orphan removed");
    let _ = fs::remove_dir_all(&work);

    // (b) segment file fully written, manifest not yet committed: the
    // WAL still covers everything; the segment is an orphan.
    let work = tmpdir("window-b");
    copy_dir(&pre, &work);
    fs::copy(
        post.join("seg-00000000.bic"),
        work.join("seg-00000000.bic"),
    )
    .unwrap();
    let store = Store::recover(&work, no_autoflush()).unwrap();
    assert_eq!(store.num_segments(), 0, "uncommitted segment ignored");
    assert_eq!(store.memtable_batches(), k);
    assert_store_matches(&store, &expect, "window b");
    assert!(!work.join("seg-00000000.bic").exists(), "orphan removed");
    let _ = fs::remove_dir_all(&work);

    // (c) manifest committed, old WAL generation left behind: replay
    // must use the new (empty) generation — no double count.
    let work = tmpdir("window-c");
    copy_dir(&post, &work);
    fs::copy(pre.join("wal-00000000.log"), work.join("wal-00000000.log"))
        .unwrap();
    let store = Store::recover(&work, no_autoflush()).unwrap();
    assert_eq!(store.num_segments(), 1);
    assert_eq!(store.memtable_batches(), 0, "stale WAL not replayed");
    assert_store_matches(&store, &expect, "window c");
    assert!(!work.join("wal-00000000.log").exists(), "stale WAL removed");
    let _ = fs::remove_dir_all(&work);

    let _ = fs::remove_dir_all(&pre);
    let _ = fs::remove_dir_all(&post);
}

#[test]
fn compaction_preserves_queries_and_tombstones_files() {
    let dist = ContentDist::Clustered { spread: 16 };
    let seed = 0xC0DE;
    let k = 12;
    let dir = tmpdir("compact");
    // Flush every batch: 12 one-batch segments. The size-tiered picker
    // sees one run of similar-size segments and merges it wholesale, so
    // the live count lands at or under the policy bound.
    let cfg = StoreConfig {
        flush_batches: 1,
        compaction: sotb_bic::store::compaction::CompactionPolicy {
            max_segments: 3,
            ..Default::default()
        },
        ..StoreConfig::default()
    };
    let mut store = Store::create(&dir, CFG.m_keys, cfg.clone()).unwrap();
    for ci in &encoded_batches(dist, seed, k) {
        store.append_batch(ci).unwrap();
    }
    assert_eq!(store.num_segments(), k);
    let expect = reference(dist, seed, k);
    assert_store_matches(&store, &expect, "pre-compaction");

    let rounds = store.compact().unwrap();
    assert!(rounds > 0);
    let live_count = store.num_segments();
    assert!(live_count <= 3, "policy bound reached (got {live_count})");
    assert_store_matches(&store, &expect, "post-compaction");

    // Superseded files are gone; exactly the live set remains on disk.
    let live: Vec<String> = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().into_string().ok())
        .filter(|n| n.starts_with("seg-"))
        .collect();
    assert_eq!(live.len(), live_count, "tombstoned files unlinked: {live:?}");

    // And the compacted store recovers identically.
    drop(store);
    let store = Store::open(&dir, cfg).unwrap();
    assert_eq!(store.num_segments(), live_count);
    assert_store_matches(&store, &expect, "recovered post-compaction");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn background_compactor_converges_under_ingest() {
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    let dist = ContentDist::Uniform;
    let seed = 0xBA09;
    let k = 10;
    let dir = tmpdir("bg-compact");
    let cfg = StoreConfig {
        flush_batches: 1,
        compaction: sotb_bic::store::compaction::CompactionPolicy {
            max_segments: 2,
            ..Default::default()
        },
        ..StoreConfig::default()
    };
    let store =
        Arc::new(Mutex::new(Store::create(&dir, CFG.m_keys, cfg).unwrap()));
    let compactor = sotb_bic::store::Compactor::spawn(
        Arc::clone(&store),
        Duration::from_millis(1),
    );
    for ci in &encoded_batches(dist, seed, k) {
        store.lock().unwrap().append_batch(ci).unwrap();
    }
    // Give the compactor time to drain, then stop it deterministically.
    for _ in 0..500 {
        if store.lock().unwrap().num_segments() <= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    compactor.stop();
    let mut guard = store.lock().unwrap();
    guard.compact().unwrap(); // deterministic finish
    assert!(guard.num_segments() <= 2);
    assert_store_matches(&guard, &reference(dist, seed, k), "background");
    drop(guard);
    let _ = fs::remove_dir_all(&dir);
}

/// Group-commit ordering: concurrent appenders submit under the store
/// lock and wait outside it; after every ticket acknowledges, the WAL
/// (replayed by recovery) must hold exactly the submitted batches in
/// submission order — ack order can never disagree with record order.
#[test]
fn group_commit_ack_order_matches_wal_order() {
    use std::sync::{Arc, Mutex};

    let threads = 4usize;
    let per_thread = 6usize;
    let dir = tmpdir("group-order");
    let store = Arc::new(Mutex::new(
        Store::create(&dir, CFG.m_keys, no_autoflush()).unwrap(),
    ));
    // Unique batch content per (thread, index) so the final index pins
    // the exact interleaving.
    let batches: Vec<Vec<CompressedIndex>> = (0..threads)
        .map(|t| {
            encoded_batches(ContentDist::Uniform, 0x9_0000 + t as u64, per_thread)
        })
        .collect();
    // Submission order, recorded while the store lock is held — by
    // construction identical to memtable (and WAL submit) order.
    let order: Arc<Mutex<Vec<(usize, usize)>>> =
        Arc::new(Mutex::new(Vec::new()));

    std::thread::scope(|s| {
        for (t, thread_batches) in batches.iter().enumerate() {
            let store = Arc::clone(&store);
            let order = Arc::clone(&order);
            s.spawn(move || {
                for (i, ci) in thread_batches.iter().enumerate() {
                    let ticket = {
                        let mut g = store.lock().unwrap();
                        let ticket = g.begin_append_batch(ci).unwrap();
                        order.lock().unwrap().push((t, i));
                        ticket
                    };
                    // The durability wait happens outside the store
                    // lock: concurrent waiters ride one group commit.
                    ticket.wait().unwrap();
                }
            });
        }
    });

    let order = order.lock().unwrap().clone();
    assert_eq!(order.len(), threads * per_thread);
    let n = CFG.n_records;
    let total = order.len() * n;
    let mut rows = vec![sotb_bic::bic::Bitmap::zeros(total); CFG.m_keys];
    for (pos, &(t, i)) in order.iter().enumerate() {
        for (a, row) in rows.iter_mut().enumerate() {
            batches[t][i].rows()[a].or_into_at(row, pos * n);
        }
    }
    let expect = BitmapIndex::from_rows(rows);

    // Every ticket acknowledged; the live handle agrees with the
    // recorded order...
    assert_store_matches(&store.lock().unwrap(), &expect, "live interleaving");
    // ...and so does recovery, which reads the WAL records in file
    // order: ack order == WAL order.
    drop(store);
    let store = Store::recover(&dir, no_autoflush()).unwrap();
    assert_eq!(store.memtable_batches(), threads * per_thread);
    assert_store_matches(&store, &expect, "recovered interleaving");
    let _ = fs::remove_dir_all(&dir);
}

/// Pre-zone-map (version 1) segment files still open and query
/// bit-identically: rewrite a flushed v2 segment into the v1 layout
/// (same payload bytes, 12-byte directory entries, no cardinalities)
/// and recover over it.
#[test]
fn pre_zone_map_v1_segments_reopen_and_query_correctly() {
    use sotb_bic::substrate::crc::crc32;

    let dist = ContentDist::Clustered { spread: 8 };
    let seed = 0x51E6;
    let (k, k2) = (4usize, 3usize);
    let dir = tmpdir("v1-compat");
    let all = encoded_batches(dist, seed, k + k2);
    let mut store = Store::create(&dir, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &all[..k] {
        store.append_batch(ci).unwrap();
    }
    store.flush().unwrap().expect("non-empty flush");
    drop(store);

    // Transcode seg-00000000.bic to the v1 layout byte-for-byte: keep
    // the header fields and row payloads, drop the per-row cardinality
    // column from the directory, restamp the CRC.
    let seg_path = dir.join("seg-00000000.bic");
    let v2 = fs::read(&seg_path).unwrap();
    assert_eq!(&v2[..8], b"BICSEG2\0", "flush writes the zoned format");
    let m = u32::from_le_bytes([v2[32], v2[33], v2[34], v2[35]]) as usize;
    assert_eq!(m, CFG.m_keys);
    let body = &v2[..v2.len() - 4];
    let v2_dir_end = 36 + 20 * m;
    let mut v1 = Vec::with_capacity(v2.len());
    v1.extend_from_slice(b"BICSEG1\0");
    v1.extend_from_slice(&v2[8..36]); // id, base, nbits, m
    let mut offset = 36 + 12 * m;
    for i in 0..m {
        let e = 36 + 20 * i;
        let len =
            u32::from_le_bytes([v2[e + 8], v2[e + 9], v2[e + 10], v2[e + 11]]);
        v1.extend_from_slice(&(offset as u64).to_le_bytes());
        v1.extend_from_slice(&len.to_le_bytes());
        offset += len as usize;
    }
    v1.extend_from_slice(&body[v2_dir_end..]);
    let crc = crc32(&v1);
    v1.extend_from_slice(&crc.to_le_bytes());
    fs::write(&seg_path, &v1).unwrap();

    // Recovery loads the v1 file (zone-unknown) and queries exactly.
    let mut store = Store::open(&dir, no_autoflush()).unwrap();
    assert_eq!(store.num_segments(), 1);
    assert_store_matches(&store, &reference(dist, seed, k), "v1 reopened");

    // Later writes upgrade naturally: more batches, a flush, and a
    // compaction down to one segment rewrite everything zoned, still
    // bit-identical.
    for ci in &all[k..] {
        store.append_batch(ci).unwrap();
    }
    store.flush().unwrap();
    drop(store);
    let compact_cfg = StoreConfig {
        flush_batches: 0,
        compaction: sotb_bic::store::compaction::CompactionPolicy {
            max_segments: 1,
            ..Default::default()
        },
        ..StoreConfig::default()
    };
    let mut store = Store::open(&dir, compact_cfg).unwrap();
    store.compact().unwrap();
    assert_eq!(store.num_segments(), 1);
    assert_store_matches(
        &store,
        &reference(dist, seed, k + k2),
        "v1 + v2 merged",
    );
    let _ = fs::remove_dir_all(&dir);
}

/// The sharded coordinator path persists worker-encoded batches in
/// input order, and the result equals the sequential reference.
#[test]
fn sharded_persist_matches_reference() {
    let dist = ContentDist::Zipf { s: 1.3 };
    let seed = 0x5A4D;
    let k = 8;
    let dir = tmpdir("sharded");
    let mut g = WorkloadGen::new(CFG, dist, seed);
    let batches: Vec<_> = (0..k).map(|i| g.batch_at(i as f64)).collect();
    let cfg = StoreConfig { flush_batches: 3, ..StoreConfig::default() };
    let mut store = Store::create(&dir, CFG.m_keys, cfg).unwrap();
    let n = ShardedIndexer::new(CFG, 3)
        .expect("shards")
        .persist_batches(&batches, &mut store)
        .unwrap();
    assert_eq!(n, k);
    assert_store_matches(&store, &reference(dist, seed, k), "sharded");
    let _ = fs::remove_dir_all(&dir);
}

// --- fault injection ----------------------------------------------------

/// The expected index when some batches sit inside quarantined
/// segments: their ranges read as all-zero holes, everything else keeps
/// its reference bits.
fn reference_with_holes(
    batches: &[CompressedIndex],
    hole: impl Fn(usize) -> bool,
) -> BitmapIndex {
    let n = CFG.n_records;
    let mut rows = vec![Bitmap::zeros(batches.len() * n); CFG.m_keys];
    for (b, ci) in batches.iter().enumerate() {
        if hole(b) {
            continue;
        }
        for (a, row) in rows.iter_mut().enumerate() {
            ci.rows()[a].or_into_at(row, b * n);
        }
    }
    BitmapIndex::from_rows(rows)
}

/// Build a store with two 3-batch segments plus one memtable batch from
/// `batches` (which must hold 7), then drop the handle.
fn build_two_segment_store(dir: &Path, batches: &[CompressedIndex]) {
    let mut store = Store::create(dir, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &batches[..3] {
        store.append_batch(ci).unwrap();
    }
    store.flush().unwrap().expect("segment 0");
    for ci in &batches[3..6] {
        store.append_batch(ci).unwrap();
    }
    store.flush().unwrap().expect("segment 1");
    store.append_batch(&batches[6]).unwrap();
}

/// A committed segment that is missing or fails its checksum must be a
/// *typed* outcome at open, never a panic or a silent skip: `Corrupt`
/// naming the path under `FailClosed`, a quarantine tombstone (file
/// moved to `quarantined/`, its range served as zeros) under
/// `ServeHealthy`.
#[test]
fn damaged_committed_segment_is_typed_under_both_policies() {
    let dist = ContentDist::Zipf { s: 1.2 };
    let seed = 0xBAD_5E6;
    let k = 7;
    let batches = encoded_batches(dist, seed, k);
    let src = tmpdir("damage-src");
    build_two_segment_store(&src, &batches);
    // Segment 0 (batches 0..3) is the victim; 3..7 stay healthy.
    let expect = reference_with_holes(&batches, |b| b < 3);

    for damage in ["missing", "crc"] {
        let work = tmpdir(&format!("damage-{damage}"));
        copy_dir(&src, &work);
        let victim = work.join("seg-00000000.bic");
        match damage {
            "missing" => fs::remove_file(&victim).unwrap(),
            _ => {
                let mut bytes = fs::read(&victim).unwrap();
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x40;
                fs::write(&victim, &bytes).unwrap();
            }
        }

        // FailClosed (the default): a typed Corrupt naming the path.
        match Store::open(&work, no_autoflush()) {
            Err(StoreError::Corrupt { what: "segment", detail }) => {
                assert!(
                    detail.contains("seg-00000000.bic"),
                    "{damage}: error names the file, got: {detail}"
                );
            }
            Err(other) => panic!("{damage}: expected Corrupt, got {other}"),
            Ok(_) => panic!("{damage}: damaged store opened fail-closed"),
        }

        // ServeHealthy: open succeeds, the victim is tombstoned, its
        // range reads as zeros, and the gap is surfaced in counters.
        let serve = StoreConfig {
            degraded: DegradedPolicy::ServeHealthy,
            flush_batches: 0,
            ..StoreConfig::default()
        };
        let store = Store::open(&work, serve).unwrap();
        assert_eq!(store.degraded_segments(), 1, "{damage}");
        assert_eq!(store.rows_unavailable(), 3 * CFG.n_records, "{damage}");
        assert_eq!(store.num_segments(), 1, "{damage}: healthy survivor");
        assert_eq!(store.memtable_batches(), 1, "{damage}: WAL replayed");
        assert_eq!(store.quarantined_entries().len(), 1, "{damage}");
        assert_eq!(
            store.quarantined_entries()[0].file, "seg-00000000.bic",
            "{damage}"
        );
        assert!(!victim.exists(), "{damage}: no longer live");
        if damage == "crc" {
            // The bytes were moved aside, not deleted — salvageable.
            assert!(
                work.join("quarantined").join("seg-00000000.bic").exists(),
                "crc: quarantined copy kept"
            );
        }
        assert_store_matches(&store, &expect, &format!("{damage} degraded"));
        drop(store);

        // The tombstone was committed: even a FailClosed reopen now
        // succeeds (refusing reads is the engine's job) and agrees.
        let store = Store::open(&work, no_autoflush()).unwrap();
        assert_eq!(store.degraded_segments(), 1, "{damage}: durable");
        assert_store_matches(
            &store,
            &expect,
            &format!("{damage} tombstone reopened"),
        );
        let _ = fs::remove_dir_all(&work);
    }
    let _ = fs::remove_dir_all(&src);
}

/// The scrubber catches rot that happens *behind* a live store: a
/// flushed segment corrupted on disk is quarantined by the next pass
/// (manifest tombstone + `quarantined/` move) while the handle keeps
/// serving the healthy remainder.
#[test]
fn scrub_quarantines_rotten_segment_and_keeps_serving() {
    let dist = ContentDist::Uniform;
    let seed = 0x5C0B;
    let k = 6;
    let dir = tmpdir("scrub");
    let batches = encoded_batches(dist, seed, k);
    let mut store = Store::create(&dir, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &batches[..3] {
        store.append_batch(ci).unwrap();
    }
    store.flush().unwrap().expect("segment 0");
    for ci in &batches[3..] {
        store.append_batch(ci).unwrap();
    }
    store.flush().unwrap().expect("segment 1");

    // A clean pass verifies everything and quarantines nothing.
    let report = store.scrub().unwrap();
    assert_eq!(report.segments_checked, 2);
    assert!(report.bytes_verified > 0);
    assert!(report.quarantined.is_empty());
    assert_eq!(report.degraded_segments, 0);
    assert_eq!(report.rows_unavailable, 0);

    // Rot segment 1 on disk behind the store's back.
    let path = dir.join("seg-00000001.bic");
    let mut bytes = fs::read(&path).unwrap();
    let at = bytes.len() - 5;
    bytes[at] ^= 1;
    fs::write(&path, &bytes).unwrap();

    let report = store.scrub().unwrap();
    assert_eq!(report.segments_checked, 1, "only the healthy one verifies");
    assert_eq!(report.quarantined, vec!["seg-00000001.bic".to_string()]);
    assert_eq!(report.degraded_segments, 1);
    assert_eq!(report.rows_unavailable, 3 * CFG.n_records);
    assert!(dir.join("quarantined").join("seg-00000001.bic").exists());
    assert!(!path.exists());

    // The healthy remainder still serves; the hole reads as zeros.
    let expect = reference_with_holes(&batches, |b| b >= 3);
    assert_store_matches(&store, &expect, "post-scrub");

    // A second pass is a no-op over the degraded-but-stable store.
    let report = store.scrub().unwrap();
    assert_eq!(report.segments_checked, 1);
    assert!(report.quarantined.is_empty());
    assert_eq!(report.degraded_segments, 1);

    // The tombstone is durable: recovery agrees bit-for-bit.
    drop(store);
    let store = Store::open(&dir, no_autoflush()).unwrap();
    assert_eq!(store.degraded_segments(), 1);
    assert_store_matches(&store, &expect, "post-scrub reopened");
    let _ = fs::remove_dir_all(&dir);
}

/// Injected rename failures and ENOSPC at *every* operation of a flush:
/// the flush fails typed, the live handle keeps serving the pre-flush
/// state, and recovery over the real filesystem sees a consistent store
/// — either the old WAL state or the completed flush, never in between.
#[test]
fn flush_faults_leave_store_consistent_at_every_op() {
    let dist = ContentDist::Clustered { spread: 8 };
    let seed = 0xF417;
    let k = 4;
    let src = tmpdir("flush-fault-src");
    let mut store = Store::create(&src, CFG.m_keys, no_autoflush()).unwrap();
    for ci in &encoded_batches(dist, seed, k) {
        store.append_batch(ci).unwrap();
    }
    drop(store);
    let expect = reference(dist, seed, k);

    // Measure how many VFS operations one open + flush performs.
    let work = tmpdir("flush-fault-measure");
    copy_dir(&src, &work);
    let probe = FaultVfs::counting(seed);
    let probe_vfs: Arc<dyn sotb_bic::store::Vfs> = Arc::clone(&probe);
    let cfg = StoreConfig {
        flush_batches: 0,
        vfs: probe_vfs,
        ..StoreConfig::default()
    };
    let mut store = Store::open(&work, cfg).unwrap();
    store.flush().unwrap().expect("non-empty");
    drop(store);
    let total = probe.ops();
    assert!(total > 0);
    let _ = fs::remove_dir_all(&work);

    for kind in [FaultKind::RenameFail, FaultKind::WriteNoSpace] {
        for op in 0..total {
            let ctx = format!("{kind:?} at op {op}");
            let work = tmpdir("flush-fault-work");
            copy_dir(&src, &work);
            let vfs: Arc<dyn sotb_bic::store::Vfs> =
                FaultVfs::with_plan(seed, vec![FaultSpec { at_op: op, kind }]);
            let cfg = StoreConfig {
                flush_batches: 0,
                vfs,
                ..StoreConfig::default()
            };
            // Neither kind applies to the read-only ops recovery
            // performs, so the open itself always succeeds.
            let mut store = Store::open(&work, cfg).unwrap();
            match store.flush() {
                Ok(_) => {} // the fault landed on an inapplicable op
                Err(StoreError::Io(_)) => {
                    // The failed flush must not lose the memtable: the
                    // live handle still serves the whole prefix.
                    assert_store_matches(
                        &store,
                        &expect,
                        &format!("{ctx}: live after failed flush"),
                    );
                }
                Err(other) => panic!("{ctx}: unexpected {other}"),
            }
            drop(store);
            let store = Store::open(&work, no_autoflush()).unwrap();
            assert_store_matches(&store, &expect, &format!("{ctx}: recovered"));
            let _ = fs::remove_dir_all(&work);
        }
    }
    let _ = fs::remove_dir_all(&src);
}

// --- engine-level fault injection ---------------------------------------

/// Schema keys for the engine-level tests: 6 values (the store
/// geometry's attribute count) drawn from the workload's byte range.
const EKEYS: [i32; 6] = [2, 5, 23, 77, 130, 251];

fn engine_builder() -> sotb_bic::engine::EngineBuilder {
    sotb_bic::engine::Engine::builder(
        sotb_bic::engine::Schema::single("byte", EKEYS).expect("schema"),
    )
    .batch_records(CFG.n_records)
    .record_words(CFG.w_words)
}

/// Raw record batches for engine ingest (the engine indexes them under
/// the schema keys, not the workload's).
fn engine_batches(dist: ContentDist, seed: u64, k: usize) -> Vec<Vec<Vec<i32>>> {
    let mut g = WorkloadGen::new(CFG, dist, seed);
    (0..k).map(|i| g.batch_at(i as f64).records).collect()
}

/// Golden-model replay of the engine's ingest: index every batch under
/// the schema keys and concatenate, zeroing batches `hole` marks.
fn engine_reference(
    batch_records: &[Vec<Vec<i32>>],
    hole: impl Fn(usize) -> bool,
) -> BitmapIndex {
    let mut core = BicCore::new(CFG);
    let n = batch_records.len() * CFG.n_records;
    let mut rows = vec![Bitmap::zeros(n); CFG.m_keys];
    for (b, records) in batch_records.iter().enumerate() {
        if hole(b) {
            continue;
        }
        let bi = core.index(records, &EKEYS);
        for (a, row) in rows.iter_mut().enumerate() {
            row.or_at(bi.row(a), b * CFG.n_records);
        }
    }
    BitmapIndex::from_rows(rows)
}

/// Engine-level degraded reads: a store that degrades refuses queries
/// with a typed `Corrupt` under `FailClosed` (reopen *and* live query
/// path), and under `ServeHealthy` serves the healthy subset on all
/// four execution tiers while surfacing the gap in `EngineStats`.
#[test]
fn engine_degraded_reads_fail_closed_or_serve_healthy() {
    use sotb_bic::engine::{ExecPath, PallasError};

    let dist = ContentDist::Zipf { s: 1.1 };
    let seed = 0xDE64;
    let k = 6;
    let dir = tmpdir("engine-degraded");
    let records = engine_batches(dist, seed, k);
    let engine = engine_builder()
        .durable(&dir)
        .flush_batches(3)
        .build()
        .expect("create");
    for r in &records {
        engine.ingest(r).expect("ingest");
    }
    engine.close().expect("close");

    // Rot segment 0 (batches 0..3) on disk.
    let victim = dir.join("seg-00000000.bic");
    let mut bytes = fs::read(&victim).unwrap();
    bytes[40] ^= 0x10;
    fs::write(&victim, &bytes).unwrap();

    // FailClosed (default): the reopen itself refuses, typed.
    match engine_builder().durable(&dir).build() {
        Err(PallasError::Corrupt { what: "segment", detail }) => {
            assert!(detail.contains("seg-00000000.bic"), "{detail}");
        }
        other => panic!("expected Corrupt, got {:?}", other.err()),
    }

    // ServeHealthy: opens, quarantines, serves the rest with counters.
    let engine = engine_builder()
        .durable(&dir)
        .degraded(DegradedPolicy::ServeHealthy)
        .build()
        .expect("degraded open");
    let stats = engine.stats();
    assert_eq!(stats.degraded_segments, 1);
    assert_eq!(stats.rows_unavailable, 3 * CFG.n_records);
    // An on-demand scrub over the already-tombstoned store is a no-op.
    let report = engine.scrub().expect("scrub");
    assert!(report.quarantined.is_empty());
    assert_eq!(report.degraded_segments, 1);
    let expect = engine_reference(&records, |b| b < 3);
    let snap = engine.snapshot();
    for (qi, q) in query_corpus().iter().enumerate() {
        let want = q.eval(&expect).unwrap();
        for path in ExecPath::ALL {
            assert_eq!(
                engine.query_via(q, path).expect("degraded query"),
                want,
                "query {qi} via {path:?}"
            );
        }
        assert_eq!(snap.query(q).expect("snapshot query"), want, "q {qi}");
    }
    drop(snap);
    engine.close().expect("close degraded");

    // FailClosed over the committed tombstone: the store opens (the
    // damage is already quarantined truth), but every read path refuses
    // with a typed Corrupt naming the segment.
    let engine = engine_builder().durable(&dir).build().expect("reopen");
    let q = Query::attr(0);
    for path in ExecPath::ALL {
        match engine.query_via(&q, path) {
            Err(PallasError::Corrupt { what: "segment", detail }) => {
                assert!(detail.contains("seg-00000000.bic"), "{detail}");
                assert!(detail.contains("FailClosed"), "{detail}");
            }
            other => panic!("{path:?}: expected Corrupt, got {other:?}"),
        }
    }
    let snap = engine.snapshot();
    assert!(matches!(
        snap.query(&q),
        Err(PallasError::Corrupt { what: "segment", .. })
    ));
    let _ = fs::remove_dir_all(&dir);
}

/// The chaos crux: crash the engine at every VFS operation of a full
/// create → ingest → auto-flush workload, recover over the real
/// filesystem, and require (a) the recovered object count to be a whole
/// number of batches inside the acked..=submitted window and (b) all
/// four query execution tiers bit-identical to the reference prefix.
/// Seeded and reproducible: failures print the seed; set `CHAOS_SEED`
/// to replay one.
#[test]
fn chaos_crash_matrix_recovers_acked_prefix_on_all_tiers() {
    use sotb_bic::engine::ExecPath;

    let seed: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC4A0_5EED);
    println!("CHAOS_SEED={seed} (set the env var to replay)");
    let dist = ContentDist::Zipf { s: 1.2 };
    let k = 5;
    let records = engine_batches(dist, seed, k);

    // Measure the op count of one fault-free run of the workload.
    let dir = tmpdir("chaos-measure");
    let probe = FaultVfs::counting(seed);
    let engine = engine_builder()
        .durable(&dir)
        .flush_batches(2)
        .vfs(Arc::clone(&probe))
        .build()
        .expect("measure build");
    for r in &records {
        engine.ingest(r).expect("measure ingest");
    }
    engine.close().expect("measure close");
    let total = probe.ops();
    assert!(total > 0, "the workload must touch the vfs");
    let _ = fs::remove_dir_all(&dir);

    // Sweep every op as a crash point (strided only if the workload
    // ever grows past ~2x its current op count).
    let stride = (total / 128).max(1) as usize;
    for op in (0..total).step_by(stride) {
        let dir = tmpdir("chaos-crash");
        let mut acked = 0usize;
        if let Ok(engine) = engine_builder()
            .durable(&dir)
            .flush_batches(2)
            .vfs(FaultVfs::crash_at(seed, op))
            .build()
        {
            for r in &records {
                match engine.ingest(r) {
                    Ok(_) => acked += 1,
                    Err(_) => break, // the vfs is dead from here on
                }
            }
            let _ = engine.close();
        }

        // Recover over the real filesystem (a crash before the store
        // commit recovers to an empty store via the create path).
        let engine = engine_builder()
            .durable(&dir)
            .flush_batches(2)
            .build()
            .unwrap_or_else(|e| {
                panic!("CHAOS_SEED={seed} op {op}: recovery failed: {e}")
            });
        let objects = engine.num_objects();
        assert_eq!(
            objects % CFG.n_records,
            0,
            "CHAOS_SEED={seed} op {op}: a partial batch survived"
        );
        let recovered = objects / CFG.n_records;
        assert!(
            (acked..=k).contains(&recovered),
            "CHAOS_SEED={seed} op {op}: recovered {recovered} batches, \
             acked {acked}, submitted {k}"
        );
        let expect = engine_reference(&records[..recovered], |_| false);
        for (qi, q) in query_corpus().iter().enumerate() {
            let want = q.eval(&expect).unwrap();
            for path in ExecPath::ALL {
                let got = engine.query_via(q, path).unwrap_or_else(|e| {
                    panic!(
                        "CHAOS_SEED={seed} op {op}: query {qi} via \
                         {path:?}: {e}"
                    )
                });
                assert_eq!(
                    got, want,
                    "CHAOS_SEED={seed} op {op}: query {qi} via {path:?}"
                );
            }
        }
        drop(engine);
        let _ = fs::remove_dir_all(&dir);
    }
}
