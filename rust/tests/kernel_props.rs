//! Kernel-tier parity properties: every dispatched kernel in
//! `bic::kernel` must be **bit-identical** to the retained scalar
//! reference ([`kernel::SCALAR`]) — across ragged word tails, empty
//! slices, all-zeros/all-ones saturation, and random densities — and
//! both tiers must agree with an independent brute-force reference, so
//! a broken SIMD lane cannot hide behind a matching scalar bug.
//!
//! The bitmap-level twin drives the same kernels through [`Bitmap`]
//! algebra at the ISSUE's ragged bit widths (0, 1, 63, 64, 65,
//! 4096 ± 1), and the WAH property pins `compress_with`/
//! `decompress_with` word-identical through both tiers. On a host
//! without AVX2 (or under `PALLAS_KERNEL_TIER=scalar` — the ci.sh
//! force-scalar leg) the dispatched table *is* the scalar table and
//! every parity check degenerates to self-comparison, which is exactly
//! the bit-identical guarantee the override promises.

use sotb_bic::bic::kernel::{self, Kernels, SCALAR};
use sotb_bic::bic::{Bitmap, WahBitmap};
use sotb_bic::substrate::proptest::{check, Gen};

/// Word-slice lengths covering empty input, sub-vector tails (< 4
/// words), the vector width and every tail residue around it, and a
/// bulk length.
const WORD_LENS: [usize; 10] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 67];

/// The ISSUE's ragged bit widths for the bitmap-level twin.
const BIT_LENS: [usize; 8] = [0, 1, 63, 64, 65, 4095, 4096, 4097];

fn arb_words(g: &mut Gen, n: usize) -> Vec<u64> {
    // Mix saturated and random words so fills, runs, and tails all get
    // exercised at every length.
    g.vec(n, |g| match g.usize_in(0, 3) {
        0 => 0,
        1 => u64::MAX,
        _ => g.u64(),
    })
}

fn arb_bitmap(g: &mut Gen, nbits: usize) -> Bitmap {
    let density = match g.usize_in(0, 3) {
        0 => 0.0,
        1 => 1.0,
        _ => g.f64_in(0.0, 1.0),
    };
    let bits: Vec<bool> = (0..nbits).map(|_| g.chance(density)).collect();
    Bitmap::from_bools(&bits)
}

#[test]
fn binary_kernels_match_scalar_and_brute_force() {
    let d: &Kernels = kernel::table();
    type Bin = fn(&mut [u64], &[u64]);
    let cases: [(&str, Bin, Bin, fn(u64, u64) -> u64); 4] = [
        ("and", SCALAR.and, d.and, |a, b| a & b),
        ("or", SCALAR.or, d.or, |a, b| a | b),
        ("xor", SCALAR.xor, d.xor, |a, b| a ^ b),
        ("and_not", SCALAR.and_not, d.and_not, |a, b| a & !b),
    ];
    check("kernel-binops", 0x4B00, 120, |g| {
        let n = WORD_LENS[g.usize_in(0, WORD_LENS.len() - 1)];
        let dst = arb_words(g, n);
        let src = arb_words(g, n);
        for (name, sc, dp, word) in cases {
            let mut a = dst.clone();
            let mut b = dst.clone();
            sc(&mut a, &src);
            dp(&mut b, &src);
            let expect: Vec<u64> =
                dst.iter().zip(&src).map(|(&x, &y)| word(x, y)).collect();
            if a != expect {
                return Err(format!("scalar {name} vs brute force, n={n}"));
            }
            if b != expect {
                return Err(format!("dispatched {name} vs brute force, n={n}"));
            }
        }
        Ok(())
    });
}

#[test]
fn unary_and_fill_kernels_match_scalar() {
    let d = kernel::table();
    check("kernel-unary", 0x4B01, 120, |g| {
        let n = WORD_LENS[g.usize_in(0, WORD_LENS.len() - 1)];
        let dst = arb_words(g, n);
        let mut a = dst.clone();
        let mut b = dst.clone();
        (SCALAR.not)(&mut a);
        (d.not)(&mut b);
        let expect: Vec<u64> = dst.iter().map(|&w| !w).collect();
        if a != expect || b != expect {
            return Err(format!("not parity, n={n}"));
        }
        let v = if g.bool() { u64::MAX } else { g.u64() };
        (SCALAR.fill)(&mut a, v);
        (d.fill)(&mut b, v);
        if a != vec![v; n] || a != b {
            return Err(format!("fill parity, n={n}"));
        }
        Ok(())
    });
}

#[test]
fn and_live_matches_scalar_words_and_liveness() {
    let d = kernel::table();
    check("kernel-and-live", 0x4B02, 120, |g| {
        let n = WORD_LENS[g.usize_in(0, WORD_LENS.len() - 1)];
        let dst = arb_words(g, n);
        // Force the dead-block case often: all-zero src kills the OR.
        let src = if g.chance(0.25) { vec![0; n] } else { arb_words(g, n) };
        let mut a = dst.clone();
        let mut b = dst.clone();
        let la = (SCALAR.and_live)(&mut a, &src);
        let lb = (d.and_live)(&mut b, &src);
        if a != b {
            return Err(format!("and_live words diverge, n={n}"));
        }
        let any = a.iter().fold(0u64, |x, &w| x | w);
        if (la != 0) != (any != 0) || (lb != 0) != (any != 0) {
            return Err(format!("and_live liveness diverges, n={n}"));
        }
        Ok(())
    });
}

#[test]
fn count_and_runs_match_scalar_and_bit_reference() {
    let d = kernel::table();
    check("kernel-count-runs", 0x4B03, 120, |g| {
        let n = WORD_LENS[g.usize_in(0, WORD_LENS.len() - 1)];
        let words = arb_words(g, n);
        let bits: Vec<bool> = (0..n * 64)
            .map(|i| words[i / 64] >> (i % 64) & 1 == 1)
            .collect();
        let ones = bits.iter().filter(|&&b| b).count();
        let runs = bits
            .iter()
            .enumerate()
            .filter(|&(i, &b)| b && (i == 0 || !bits[i - 1]))
            .count();
        if (SCALAR.count_ones)(&words) != ones
            || (d.count_ones)(&words) != ones
        {
            return Err(format!("count_ones parity, n={n}"));
        }
        if (SCALAR.one_runs)(&words) != runs || (d.one_runs)(&words) != runs {
            return Err(format!("one_runs parity, n={n}"));
        }
        Ok(())
    });
}

#[test]
fn transpose64_matches_scalar_and_definition() {
    let d = kernel::table();
    check("kernel-transpose64", 0x4B04, 120, |g| {
        let mut tile = [0u64; 64];
        for w in tile.iter_mut() {
            *w = match g.usize_in(0, 3) {
                0 => 0,
                1 => u64::MAX,
                _ => g.u64(),
            };
        }
        let mut a = tile;
        let mut b = tile;
        (SCALAR.transpose64)(&mut a);
        (d.transpose64)(&mut b);
        if a != b {
            return Err("transpose64 tiers diverge".into());
        }
        for i in 0..64 {
            for j in 0..64 {
                if a[j] >> i & 1 != tile[i] >> j & 1 {
                    return Err(format!("transpose64 bit ({i},{j}) wrong"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn uniform_span_matches_scalar_everywhere() {
    let d = kernel::table();
    check("kernel-uniform-span", 0x4B05, 200, |g| {
        let n = WORD_LENS[g.usize_in(0, WORD_LENS.len() - 1)];
        // Run-heavy words so spans of every length occur.
        let words = g.vec(n, |g| if g.bool() { 0 } else { u64::MAX });
        let from = g.usize_in(0, n + 2);
        for value in [0u64, u64::MAX, 7] {
            let expect = if from >= n {
                0
            } else {
                words[from..].iter().take_while(|&&w| w == value).count()
            };
            if (SCALAR.uniform_span)(&words, from, value) != expect {
                return Err(format!("scalar span, n={n} from={from}"));
            }
            if (d.uniform_span)(&words, from, value) != expect {
                return Err(format!("dispatched span, n={n} from={from}"));
            }
        }
        Ok(())
    });
}

#[test]
fn bitmap_algebra_is_tier_invariant_at_ragged_widths() {
    // The Bitmap facade routes through the dispatched table; pin it
    // against a bool-level model at every ragged width, so the tail
    // invariant (bits past nbits stay zero) survives the SIMD tier.
    check("kernel-bitmap-twin", 0x4B06, 80, |g| {
        let n = BIT_LENS[g.usize_in(0, BIT_LENS.len() - 1)];
        let a = arb_bitmap(g, n);
        let b = arb_bitmap(g, n);
        let pairs: [(&str, Bitmap, fn(bool, bool) -> bool); 4] = [
            ("and", a.and(&b), |x, y| x & y),
            ("or", a.or(&b), |x, y| x | y),
            ("xor", a.xor(&b), |x, y| x ^ y),
            ("and_not", a.and_not(&b), |x, y| x & !y),
        ];
        for (name, got, bit) in pairs {
            let expect = Bitmap::from_bools(
                &(0..n).map(|i| bit(a.get(i), b.get(i))).collect::<Vec<_>>(),
            );
            if got != expect {
                return Err(format!("bitmap {name} diverges at n={n}"));
            }
        }
        if a.not() != Bitmap::from_bools(
            &(0..n).map(|i| !a.get(i)).collect::<Vec<_>>(),
        ) {
            return Err(format!("bitmap not diverges at n={n}"));
        }
        let ones = (0..n).filter(|&i| a.get(i)).count();
        if a.count_ones() != ones {
            return Err(format!("bitmap count_ones diverges at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn wah_round_trips_word_identical_through_both_tiers() {
    let d = kernel::table();
    check("kernel-wah-tiers", 0x4B07, 60, |g| {
        let n = BIT_LENS[g.usize_in(0, BIT_LENS.len() - 1)];
        let bm = arb_bitmap(g, n);
        let ws = WahBitmap::compress_with(&bm, &SCALAR);
        let wd = WahBitmap::compress_with(&bm, d);
        if ws != wd {
            return Err(format!("compress_with tiers diverge at n={n}"));
        }
        if WahBitmap::compress(&bm) != wd {
            return Err(format!("compress != dispatched compress_with, n={n}"));
        }
        if ws.decompress_with(&SCALAR) != bm || ws.decompress_with(d) != bm {
            return Err(format!("decompress_with round-trip fails at n={n}"));
        }
        Ok(())
    });
}

#[test]
fn tier_honors_the_env_override() {
    let label = kernel::tier().label();
    assert_eq!(kernel::table().label, label);
    match std::env::var("PALLAS_KERNEL_TIER").ok().as_deref() {
        Some(v) if v.eq_ignore_ascii_case("scalar") => {
            assert_eq!(label, "scalar", "override must force the scalar tier")
        }
        _ => assert!(
            label == "scalar" || label == "avx2",
            "unknown tier label {label}"
        ),
    }
}
