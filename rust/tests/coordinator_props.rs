//! Property tests on coordinator invariants: routing (no batch lost or
//! duplicated, even under failure injection), latency sanity, energy
//! accounting conservation, and power-state legality.

use std::collections::HashSet;

use sotb_bic::bic::BicConfig;
use sotb_bic::coordinator::{
    ArrivalProcess, Batch, ContentDist, Policy, Scheduler, SchedulerConfig,
    WorkloadGen,
};
use sotb_bic::substrate::proptest::{check, Gen};

fn arb_policy(g: &mut Gen) -> Policy {
    match g.usize_in(0, 3) {
        0 => Policy::AlwaysOn,
        1 => Policy::CgOnly { idle_to_cg: g.f64_in(1e-5, 1e-2) },
        2 => Policy::CgThenRbb {
            idle_to_cg: g.f64_in(1e-5, 1e-2),
            cg_to_rbb: g.f64_in(1e-4, 1e-1),
        },
        _ => Policy::ImmediateRbb,
    }
}

fn arb_trace(g: &mut Gen, n_max: usize) -> Vec<Batch> {
    let mut gen = WorkloadGen::new(BicConfig::CHIP, ContentDist::Uniform, g.u64());
    let process = match g.usize_in(0, 2) {
        0 => ArrivalProcess::Steady { rate: g.f64_in(100.0, 50_000.0) },
        1 => ArrivalProcess::Diurnal {
            base: g.f64_in(10.0, 100.0),
            amp: g.f64_in(100.0, 5_000.0),
            period: g.f64_in(0.05, 0.5),
        },
        _ => ArrivalProcess::Bursty {
            rate: g.f64_in(1_000.0, 20_000.0),
            on: g.f64_in(0.01, 0.1),
            off: g.f64_in(0.01, 0.2),
        },
    };
    let mut trace = gen.trace(process, g.f64_in(0.05, 0.4));
    trace.truncate(n_max);
    trace
}

#[test]
fn no_batch_lost_or_duplicated() {
    check("routing-conservation", 0xC0, 30, |g| {
        let trace = arb_trace(g, 300);
        let offered = trace.len();
        let ids: HashSet<u64> = trace.iter().map(|b| b.id).collect();
        let mut cfg = SchedulerConfig::chip_system(g.usize_in(1, 8));
        cfg.policy = arb_policy(g);
        cfg.compute_results = false;
        let (report, completed) = Scheduler::new(cfg).run_collect(trace);
        if report.completed != offered {
            return Err(format!("{} offered, {} completed", offered, report.completed));
        }
        let done: Vec<u64> = completed.iter().map(|c| c.id).collect();
        let done_set: HashSet<u64> = done.iter().copied().collect();
        if done.len() != done_set.len() {
            return Err("duplicated completion".into());
        }
        if done_set != ids {
            return Err("completion set != offered set".into());
        }
        Ok(())
    });
}

#[test]
fn conservation_survives_core_failures() {
    check("failure-conservation", 0xC1, 25, |g| {
        let trace = arb_trace(g, 200);
        let offered = trace.len();
        let cores = g.usize_in(2, 8);
        let mut cfg = SchedulerConfig::chip_system(cores);
        cfg.policy = arb_policy(g);
        cfg.compute_results = false;
        // Kill up to cores-1 distinct cores at random times (one must
        // survive so the trace can drain).
        let n_fail = g.usize_in(1, cores - 1);
        let mut victims: Vec<usize> = (0..cores).collect();
        g.rng().shuffle(&mut victims);
        let failures: Vec<(usize, f64)> = victims[..n_fail]
            .iter()
            .map(|&c| (c, g.f64_in(0.0, 0.2)))
            .collect();
        cfg.core_failures = failures.clone();
        let (report, completed) = Scheduler::new(cfg).run_collect(trace);
        if report.completed != offered {
            return Err(format!(
                "{offered} offered, {} completed with {n_fail} failures",
                report.completed
            ));
        }
        // No completion may be attributed to a core after its death…
        // (completions strictly before the failure time are fine).
        for c in &completed {
            for &(victim, t_fail) in &failures {
                if c.core == victim && c.stored > t_fail + 1e-9 && c.completed > t_fail {
                    return Err(format!(
                        "batch {} completed on core {} after its failure",
                        c.id, victim
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn latency_bounded_below_by_compute_time() {
    check("latency-floor", 0xC2, 20, |g| {
        let trace = arb_trace(g, 150);
        if trace.is_empty() {
            return Ok(());
        }
        let mut cfg = SchedulerConfig::chip_system(g.usize_in(1, 8));
        cfg.policy = arb_policy(g);
        cfg.compute_results = false;
        let compute = BicConfig::CHIP.cycles_per_batch() as f64 / cfg.frequency();
        let (report, completed) = Scheduler::new(cfg).run_collect(trace);
        let _ = report;
        for c in &completed {
            if c.latency() < compute * 0.999 {
                return Err(format!(
                    "batch {} latency {:.3e} below compute floor {:.3e}",
                    c.id,
                    c.latency(),
                    compute
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn energy_ledger_is_nonnegative_and_consistent() {
    check("energy-ledger", 0xC3, 20, |g| {
        let trace = arb_trace(g, 150);
        let mut cfg = SchedulerConfig::chip_system(g.usize_in(1, 8));
        cfg.policy = arb_policy(g);
        cfg.compute_results = false;
        let report = Scheduler::new(cfg).run(trace);
        let e = &report.energy;
        for (name, v) in [
            ("active", e.active),
            ("idle", e.idle),
            ("cg", e.cg),
            ("rbb", e.rbb),
            ("waking", e.waking),
        ] {
            if v < 0.0 {
                return Err(format!("negative {name} energy {v:.3e}"));
            }
        }
        let sum = e.active + e.idle + e.cg + e.rbb + e.waking;
        if (e.total() - sum).abs() > 1e-15 + sum * 1e-12 {
            return Err("total != sum of parts".into());
        }
        if e.overhead() > e.total() + 1e-18 {
            return Err("overhead exceeds total".into());
        }
        Ok(())
    });
}

#[test]
fn deeper_policies_never_cost_more_energy() {
    // For the SAME trace, the policy ladder ordering must hold:
    // always-on >= CG-only >= CG->RBB (wake energy is negligible next to
    // idle clock-tree burn at these time scales).
    check("policy-energy-order", 0xC4, 12, |g| {
        let trace = arb_trace(g, 120);
        if trace.is_empty() {
            return Ok(());
        }
        let run = |policy: Policy, trace: Vec<Batch>| {
            let mut cfg = SchedulerConfig::chip_system(4);
            cfg.policy = policy;
            cfg.compute_results = false;
            Scheduler::new(cfg).run(trace).energy.total()
        };
        let on = run(Policy::AlwaysOn, trace.clone());
        let cg = run(Policy::CgOnly { idle_to_cg: 1e-4 }, trace.clone());
        let ladder = run(
            Policy::CgThenRbb { idle_to_cg: 1e-4, cg_to_rbb: 1e-3 },
            trace,
        );
        if cg > on * 1.0001 {
            return Err(format!("CG {cg:.3e} > always-on {on:.3e}"));
        }
        // The ladder can cost marginally more than CG-only on tiny traces:
        // RBB wake latency stretches completions, and the whole fleet
        // leaks over the longer horizon. Allow 5%; the win must show up
        // whenever there is real idle time.
        if ladder > cg * 1.05 {
            return Err(format!("ladder {ladder:.3e} > CG {cg:.3e} by >5%"));
        }
        Ok(())
    });
}

#[test]
fn stored_never_precedes_completion() {
    check("timestamps-ordered", 0xC5, 20, |g| {
        let trace = arb_trace(g, 150);
        let mut cfg = SchedulerConfig::chip_system(g.usize_in(1, 6));
        cfg.compute_results = false;
        let (_, completed) = Scheduler::new(cfg).run_collect(trace);
        for c in &completed {
            if c.stored < c.completed - 1e-12 || c.completed < c.arrival {
                return Err(format!(
                    "batch {}: arrival {} completed {} stored {}",
                    c.id, c.arrival, c.completed, c.stored
                ));
            }
        }
        Ok(())
    });
}
