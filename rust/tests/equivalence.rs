//! Three-way equivalence: the golden functional model, the cycle-level
//! simulator, and the PJRT-executed AOT artifact must produce identical
//! bitmaps on arbitrary inputs — the repository's central correctness
//! claim (DESIGN.md §3).

use sotb_bic::bic::{conjunctive, BicConfig, BicCore, Query};
use sotb_bic::coordinator::{index_batches_sharded, ContentDist, WorkloadGen};
use sotb_bic::runtime::{BicExecutable, Manifest, Runtime};
use sotb_bic::sim::CoreSim;
use sotb_bic::substrate::proptest::{check, Gen};

fn arb_records(g: &mut Gen, n_max: usize, w: usize) -> Vec<Vec<i32>> {
    let n = g.usize_in(0, n_max);
    (0..n)
        .map(|_| {
            let len = g.usize_in(1, w);
            (0..len).map(|_| g.word()).collect()
        })
        .collect()
}

fn arb_keys(g: &mut Gen, m: usize) -> Vec<i32> {
    (0..m).map(|_| g.word()).collect()
}

#[test]
fn golden_equals_cycle_simulator_arbitrary_geometry() {
    check("golden-vs-sim", 0xE0, 40, |g| {
        let cfg = BicConfig {
            n_records: g.usize_in(1, 48),
            w_words: g.usize_in(1, 48),
            m_keys: g.usize_in(1, 24),
        };
        let mut golden = BicCore::new(cfg);
        let mut sim = CoreSim::new(cfg);
        for _ in 0..2 {
            let recs = arb_records(g, cfg.n_records, cfg.w_words);
            let keys = arb_keys(g, cfg.m_keys);
            let run = sim.index_batch(&recs, &keys);
            if run.index != golden.index(&recs, &keys) {
                return Err(format!("mismatch at cfg {cfg:?}"));
            }
            if run.cycles != cfg.cycles_per_batch() {
                return Err(format!(
                    "cycles {} != analytic {} at cfg {cfg:?}",
                    run.cycles,
                    cfg.cycles_per_batch()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn word_parallel_index_equals_scalar_reference_arbitrary_geometry() {
    // The word-parallel hot path (packed CAM rows + 64x64 block
    // transpose) against the retained scalar reference pipeline, over
    // geometries that straddle every tile boundary — including m > 64,
    // which the cycle simulator's 64-key TM cannot reach.
    check("word-parallel-vs-scalar", 0xE5, 40, |g| {
        let cfg = BicConfig {
            n_records: g.usize_in(1, 140),
            w_words: g.usize_in(1, 48),
            m_keys: g.usize_in(1, 140),
        };
        let mut core = BicCore::new(cfg);
        let recs = arb_records(g, cfg.n_records, cfg.w_words);
        let keys = arb_keys(g, cfg.m_keys);
        let fast = core.index(&recs, &keys);
        let slow = core.index_scalar(&recs, &keys);
        if fast != slow {
            return Err(format!("hot path diverged at cfg {cfg:?}"));
        }
        // The interchange artifact bytes must match too, not just Eq.
        if fast.to_packed() != slow.to_packed() {
            return Err(format!("packed artifact diverged at cfg {cfg:?}"));
        }
        Ok(())
    });
}

#[test]
fn sharded_indexer_equals_scheduler_results() {
    // The thread-sharded host path and the discrete-event scheduler must
    // produce identical bitmaps for the same trace.
    let mut g = WorkloadGen::new(BicConfig::CHIP, ContentDist::Uniform, 0xE6);
    let trace: Vec<_> = (0..24).map(|i| g.batch_at(i as f64 * 1e-5)).collect();
    let sharded = index_batches_sharded(BicConfig::CHIP, &trace, 4)
        .expect("valid trace");
    let (_, completed) = sotb_bic::coordinator::Scheduler::new(
        sotb_bic::coordinator::SchedulerConfig::chip_system(3),
    )
    .run_collect(trace);
    assert_eq!(sharded.len(), completed.len());
    for c in &completed {
        let idx = c.index.as_ref().expect("compute_results defaults on");
        assert_eq!(idx, &sharded[c.id as usize], "batch {}", c.id);
    }
}

#[test]
fn golden_equals_pjrt_on_all_variants() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    for v in manifest
        .bic
        .iter()
        .chain(manifest.twostep.iter())
        .chain(manifest.mxu.iter())
    {
        let exe = BicExecutable::load(&rt, v).unwrap();
        let cfg = BicConfig { n_records: v.n, w_words: v.w, m_keys: v.m };
        let mut golden = BicCore::new(cfg);
        let rounds = if v.n * v.w > 20_000 { 2 } else { 6 };
        check(&format!("pjrt-{}", v.name), 0xE1 + v.n as u64, rounds, |g| {
            let recs = arb_records(g, cfg.n_records, cfg.w_words);
            let keys = arb_keys(g, cfg.m_keys);
            let via_pjrt = exe.index(&recs, &keys).map_err(|e| format!("{e:#}"))?;
            if via_pjrt != golden.index(&recs, &keys) {
                return Err(format!("variant {} diverged", v.name));
            }
            Ok(())
        });
    }
}

#[test]
fn query_three_way_equivalence() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let bic_v = manifest.find_bic("batch").unwrap();
    let q_v = manifest.find_query("batch").unwrap();
    let exe = BicExecutable::load(&rt, bic_v).unwrap();
    let qexe = sotb_bic::runtime::QueryExecutable::load(&rt, q_v).unwrap();
    let cfg = BicConfig { n_records: bic_v.n, w_words: bic_v.w, m_keys: bic_v.m };

    check("query-3way", 0xE7, 8, |g| {
        let recs = arb_records(g, cfg.n_records, cfg.w_words);
        let keys = arb_keys(g, cfg.m_keys);
        let bi = exe.index(&recs, &keys).map_err(|e| format!("{e:#}"))?;
        let include: Vec<bool> = (0..cfg.m_keys).map(|_| g.chance(0.4)).collect();
        let exclude: Vec<bool> = (0..cfg.m_keys).map(|_| g.chance(0.3)).collect();

        // 1. PJRT query artifact.
        let via_pjrt = qexe.eval(&bi, &include, &exclude).map_err(|e| format!("{e:#}"))?;
        // 2. Rust conjunctive engine.
        let via_conj = conjunctive(&bi, &include, &exclude);
        if via_pjrt != via_conj.to_packed_words() {
            return Err("pjrt != conjunctive".into());
        }
        // 3. Expression-tree engine.
        let inc_q = Query::And(
            include
                .iter()
                .enumerate()
                .filter(|(_, &b)| b)
                .map(|(i, _)| Query::Attr(i))
                .collect(),
        );
        let exc_q = Query::Or(
            exclude
                .iter()
                .enumerate()
                .filter(|(_, &b)| b)
                .map(|(i, _)| Query::Attr(i))
                .collect(),
        );
        let via_expr = inc_q.and(exc_q.not()).eval(&bi).map_err(|e| e.to_string())?;
        if via_expr != via_conj {
            return Err("expression != conjunctive".into());
        }
        Ok(())
    });
}

#[test]
fn sim_activity_scales_with_geometry() {
    // Sanity on the power pipeline: more records/keys => more events.
    let small = CoreSim::new(BicConfig { n_records: 4, w_words: 8, m_keys: 4 });
    let big = CoreSim::new(BicConfig { n_records: 16, w_words: 32, m_keys: 8 });
    let mut run = |mut sim: CoreSim, seed: u64| {
        let mut g = Gen::replay(seed, 0);
        let cfg = *sim.config();
        let recs: Vec<Vec<i32>> = (0..cfg.n_records)
            .map(|_| (0..cfg.w_words).map(|_| g.word()).collect())
            .collect();
        let keys: Vec<i32> = (0..cfg.m_keys).map(|_| g.word()).collect();
        sim.index_batch(&recs, &keys).activity.total_events()
    };
    let e_small = run(small, 1);
    let e_big = run(big, 2);
    assert!(e_big > 4 * e_small, "events {e_small} -> {e_big}");
}
