//! Engine facade properties: the whole session API — ingest, flush,
//! durable reopen, planned queries, snapshots, typed errors — driven
//! exclusively through `EngineBuilder`.
//!
//! The headline property: `Engine::query` is **bit-identical** across
//! all four execution choices (raw, compressed, sharded, store-backed)
//! on all three workload content distributions, so the planner can pick
//! any tier on cost alone.

use std::fs;
use std::path::PathBuf;

use sotb_bic::bic::{BicConfig, BicCore, Bitmap, BitmapIndex, Codec, Query};
use sotb_bic::coordinator::{ContentDist, WorkloadGen};
use sotb_bic::engine::{
    col, CodecPolicy, CompactionMode, Engine, EngineBuilder, ExecPath,
    ExecPolicy, PallasError, Schema, ShardPolicy,
};

const CFG: BicConfig = BicConfig { n_records: 64, w_words: 8, m_keys: 8 };
const KEYS: [i32; 8] = [2, 5, 11, 23, 77, 130, 200, 251];

fn schema() -> Schema {
    Schema::single("byte", KEYS).expect("valid schema")
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bic-engine-props-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn builder() -> EngineBuilder {
    Engine::builder(schema())
        .batch_records(CFG.n_records)
        .record_words(CFG.w_words)
}

/// `k` batches of records under `dist` (keys come from the schema, not
/// the workload generator).
fn batches(dist: ContentDist, seed: u64, k: usize) -> Vec<Vec<Vec<i32>>> {
    let mut g = WorkloadGen::new(CFG, dist, seed);
    (0..k).map(|i| g.batch_at(i as f64).records).collect()
}

/// Golden-model replay of the engine's ingest: index every batch with
/// the schema keys and concatenate.
fn reference(batch_records: &[Vec<Vec<i32>>]) -> BitmapIndex {
    let mut core = BicCore::new(CFG);
    let n = batch_records.len() * CFG.n_records;
    let mut rows = vec![Bitmap::zeros(n); CFG.m_keys];
    for (b, records) in batch_records.iter().enumerate() {
        let bi = core.index(records, &KEYS);
        for (a, row) in rows.iter_mut().enumerate() {
            row.or_at(bi.row(a), b * CFG.n_records);
        }
    }
    BitmapIndex::from_rows(rows)
}

fn query_corpus() -> Vec<Query> {
    vec![
        Query::attr(1).and(Query::attr(3)).and(Query::attr(4).not()),
        Query::attr(0).or(Query::attr(2).not()),
        Query::And(vec![]),
        Query::Or(vec![]),
        Query::attr(5).not().not(),
        Query::attr(0)
            .and(Query::attr(1).or(Query::attr(2)))
            .and(Query::attr(3).not()),
        Query::Or(vec![
            Query::attr(4),
            Query::And(vec![Query::attr(0), Query::attr(5)]),
        ]),
        Query::And(vec![Query::attr(6).not(), Query::attr(7).not()]),
    ]
}

#[test]
fn query_is_bit_identical_across_all_four_paths() {
    for (tag, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 8 }),
    ] {
        let dir = tmpdir(&format!("paths-{tag}"));
        let engine = builder()
            .durable(&dir)
            .flush_batches(3) // 10 batches -> 3 segments + 1 memtable
            .build()
            .expect("build");
        let data = batches(dist, 0xBEEF + tag.len() as u64, 10);
        engine.ingest_batches(&data).expect("ingest");
        let expect = reference(&data);

        for (qi, q) in query_corpus().iter().enumerate() {
            let want = q.eval(&expect).expect("reference eval");
            for path in ExecPath::ALL {
                assert_eq!(
                    engine.query_via(q, path).expect("query"),
                    want,
                    "{tag}: query {qi} on {path:?}"
                );
            }
            // The planner's own choice must agree too.
            assert_eq!(
                engine.query(q).expect("planned query"),
                want,
                "{tag}: query {qi} planned"
            );
        }
        let stats = engine.close().expect("close");
        assert!(stats.queries_total() > 0);
        let _ = fs::remove_dir_all(&dir);
    }
}

#[test]
fn forced_codecs_stay_bit_identical_across_paths() {
    for codec in Codec::ALL {
        let dir = tmpdir(&format!("codec-{codec:?}"));
        let engine = builder()
            .durable(&dir)
            .flush_batches(2)
            .codec(CodecPolicy::Forced(codec))
            .build()
            .expect("build");
        let data = batches(ContentDist::Clustered { spread: 16 }, 0xC0, 7);
        engine.ingest_batches(&data).expect("ingest");
        let expect = reference(&data);
        let q = Query::attr(1).and(Query::attr(3)).and(Query::attr(5).not());
        let want = q.eval(&expect).unwrap();
        for path in ExecPath::ALL {
            assert_eq!(
                engine.query_via(&q, path).unwrap(),
                want,
                "{codec:?} on {path:?}"
            );
        }
        engine.close().expect("close");
        let _ = fs::remove_dir_all(&dir);
    }
}

#[test]
fn ingest_flush_reopen_roundtrip_through_the_facade_only() {
    let dir = tmpdir("roundtrip");
    let data = batches(ContentDist::Zipf { s: 1.3 }, 0x5EED, 11);
    let expect = reference(&data);

    // Session 1: ingest one batch at a time, auto-flush every 4, close
    // (which flushes the tail).
    let engine =
        builder().durable(&dir).flush_batches(4).build().expect("create");
    for records in &data {
        let receipt = engine.ingest(records).expect("ingest");
        assert!(receipt.durable);
        assert_eq!(receipt.objects, CFG.n_records);
    }
    let stats = engine.close().expect("close");
    assert_eq!(stats.batches_ingested, 11);

    // Session 2: reopen the same directory through the builder; the
    // close-flush means everything is in segments.
    let engine =
        builder().durable(&dir).flush_batches(4).build().expect("reopen");
    let stats = engine.stats();
    assert_eq!(stats.objects, 11 * CFG.n_records);
    assert_eq!(stats.memtable_batches, 0);
    assert!(stats.segments >= 1);
    assert_eq!(engine.snapshot().to_index(), expect, "recovered index");
    for (qi, q) in query_corpus().iter().enumerate() {
        assert_eq!(
            engine.query(q).expect("query"),
            q.eval(&expect).expect("reference"),
            "reopened query {qi}"
        );
    }
    engine.close().expect("close 2");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_pins_its_world_against_ingest_flush_and_compaction() {
    let dir = tmpdir("snapshot");
    let engine = builder()
        .durable(&dir)
        .flush_batches(1) // every batch becomes a segment
        .max_segments(2)
        .compaction(CompactionMode::Foreground)
        .build()
        .expect("build");
    let head = batches(ContentDist::Uniform, 0xA0, 3);
    engine.ingest_batches(&head).expect("ingest head");
    let snap = engine.snapshot();
    let frozen = snap.to_index();
    assert_eq!(frozen, reference(&head));

    // Later ingest triggers flushes and foreground compactions that
    // tombstone + unlink the very segment files the snapshot pinned.
    let tail = batches(ContentDist::Uniform, 0xA1, 5);
    engine.ingest_batches(&tail).expect("ingest tail");
    assert_eq!(engine.num_objects(), 8 * CFG.n_records);
    assert_eq!(snap.num_objects(), 3 * CFG.n_records);
    assert_eq!(snap.to_index(), frozen, "snapshot view must not move");
    let q = Query::attr(2).and(Query::attr(6).not());
    assert_eq!(
        snap.query(&q).expect("snapshot query"),
        q.eval(&frozen).expect("reference"),
    );
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn in_memory_engine_matches_reference_and_shards_deterministically() {
    let engine = builder()
        .workers(4)
        .shard_policy(ShardPolicy::Always)
        .build()
        .expect("build");
    let data = batches(ContentDist::Clustered { spread: 12 }, 0x11, 6);
    engine.ingest_batches(&data).expect("ingest");
    let expect = reference(&data);
    for (qi, q) in query_corpus().iter().enumerate() {
        let want = q.eval(&expect).unwrap();
        for path in [ExecPath::Raw, ExecPath::Compressed, ExecPath::Sharded] {
            assert_eq!(
                engine.query_via(q, path).unwrap(),
                want,
                "memory query {qi} on {path:?}"
            );
        }
    }
    // The in-memory backend has no store tier.
    let err = engine
        .query_via(&Query::attr(0), ExecPath::Store)
        .expect_err("no durable store");
    assert!(matches!(err, PallasError::Config(_)), "{err}");
    engine.close().expect("close");
}

#[test]
fn predicates_flow_through_the_facade() {
    let engine = builder().build().expect("build");
    let data = batches(ContentDist::Uniform, 0x77, 4);
    engine.ingest_batches(&data).expect("ingest");
    let expect = reference(&data);

    // col("byte").eq(KEYS[1]) is exactly attribute row 1.
    let pred = col("byte")
        .eq(KEYS[1])
        .and(col("byte").eq(KEYS[3]))
        .and(col("byte").eq(KEYS[4]).not());
    let q = Query::attr(1).and(Query::attr(3)).and(Query::attr(4).not());
    assert_eq!(
        engine.select(&pred).expect("select"),
        q.eval(&expect).expect("reference")
    );
    // Range predicates lower to ORs over the domain.
    let ge = col("byte").ge(100).lower(engine.schema()).expect("lower");
    assert_eq!(ge.attrs(), vec![5, 6, 7]);
    assert_eq!(
        engine.query(&ge).expect("query"),
        ge.eval(&expect).expect("reference")
    );
    engine.close().expect("close");
}

#[test]
fn typed_errors_cover_the_public_surface() {
    // Config: degenerate geometry.
    assert!(matches!(
        builder().batch_records(0).build(),
        Err(PallasError::Config(_))
    ));
    // Config: forcing the store tier without a durable path.
    assert!(matches!(
        builder().exec_policy(ExecPolicy::Force(ExecPath::Store)).build(),
        Err(PallasError::Config(_))
    ));
    // Config: compaction without a durable path.
    assert!(matches!(
        builder().compaction(CompactionMode::Foreground).build(),
        Err(PallasError::Config(_))
    ));

    let engine = builder().build().expect("build");
    // Ingest: too many records.
    let too_many = vec![vec![1i32; 4]; CFG.n_records + 1];
    assert!(matches!(
        engine.ingest(&too_many),
        Err(PallasError::Ingest(_))
    ));
    // Ingest: over-wide record.
    let too_wide = vec![vec![1i32; CFG.w_words + 1]];
    assert!(matches!(
        engine.ingest(&too_wide),
        Err(PallasError::Ingest(_))
    ));
    // InvalidQuery: attribute out of range.
    assert!(matches!(
        engine.query(&Query::attr(99)),
        Err(PallasError::InvalidQuery(_))
    ));
    // InvalidQuery: unknown column / out-of-domain value.
    assert!(matches!(
        engine.select(&col("nope").eq(1)),
        Err(PallasError::InvalidQuery(_))
    ));
    assert!(matches!(
        engine.select(&col("byte").eq(999)),
        Err(PallasError::InvalidQuery(_))
    ));
    engine.close().expect("close");

    // Config: reopening a store under a narrower schema.
    let dir = tmpdir("mismatch");
    let eight = builder().durable(&dir).build().expect("create");
    eight.close().expect("close");
    let four = Engine::builder(
        Schema::single("byte", [1, 2, 3, 4]).expect("schema"),
    )
    .batch_records(CFG.n_records)
    .record_words(CFG.w_words)
    .durable(&dir)
    .build();
    assert!(matches!(four, Err(PallasError::Config(_))));
    // Config: a *same-width* schema with different key values (or a
    // renamed column) must be rejected too — the sidecar catches what
    // the attribute count cannot, so stored rows are never silently
    // reinterpreted under the wrong keys.
    let swapped = Engine::builder(
        Schema::single("byte", [91, 92, 93, 94, 95, 96, 97, 98])
            .expect("schema"),
    )
    .batch_records(CFG.n_records)
    .record_words(CFG.w_words)
    .durable(&dir)
    .build();
    assert!(matches!(swapped, Err(PallasError::Config(_))), "key swap");
    let renamed = Engine::builder(
        Schema::single("bytes2", KEYS).expect("schema"),
    )
    .batch_records(CFG.n_records)
    .record_words(CFG.w_words)
    .durable(&dir)
    .build();
    assert!(matches!(renamed, Err(PallasError::Config(_))), "rename");
    // The original schema still reopens cleanly.
    let same = builder().durable(&dir).build().expect("same schema reopens");
    same.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

/// The skip-vs-noskip differential: zone-map pruning must never change
/// a result, on any tier, under any content distribution — it may only
/// shrink what the store tier reads.
#[test]
fn zone_pruning_never_changes_results() {
    for (tag, dist) in [
        ("uniform", ContentDist::Uniform),
        ("zipf", ContentDist::Zipf { s: 1.2 }),
        ("clustered", ContentDist::Clustered { spread: 8 }),
    ] {
        let data = batches(dist, 0x2E0 + tag.len() as u64, 10);
        let expect = reference(&data);
        let dir_on = tmpdir(&format!("zone-on-{tag}"));
        let dir_off = tmpdir(&format!("zone-off-{tag}"));
        let on = builder()
            .durable(&dir_on)
            .flush_batches(3) // segments + a memtable tail
            .build()
            .expect("build zones-on");
        let off = builder()
            .durable(&dir_off)
            .flush_batches(3)
            .zone_maps(false)
            .build()
            .expect("build zones-off");
        on.ingest_batches(&data).expect("ingest on");
        off.ingest_batches(&data).expect("ingest off");
        for (qi, q) in query_corpus().iter().enumerate() {
            let want = q.eval(&expect).expect("reference eval");
            for path in ExecPath::ALL {
                assert_eq!(
                    on.query_via(q, path).expect("query"),
                    want,
                    "{tag}: query {qi} on {path:?} with zone maps"
                );
                assert_eq!(
                    off.query_via(q, path).expect("query"),
                    want,
                    "{tag}: query {qi} on {path:?} without zone maps"
                );
            }
        }
        // Identical query streams: pruning can only reduce the bytes
        // the store tier folds, and only the pruned engine ever skips.
        let (s_on, s_off) = (on.stats(), off.stats());
        assert_eq!(s_off.store_chunks_skipped, 0, "{tag}: noskip engine");
        assert!(
            s_on.store_row_bytes_read <= s_off.store_row_bytes_read,
            "{tag}: pruning must not read more ({} > {})",
            s_on.store_row_bytes_read,
            s_off.store_row_bytes_read
        );
        on.close().expect("close on");
        off.close().expect("close off");
        let _ = fs::remove_dir_all(&dir_on);
        let _ = fs::remove_dir_all(&dir_off);
    }
}

/// The acceptance counter: on a clustered workload whose batches each
/// cluster on a single key, a conjunction over rows that never share a
/// segment reads **strictly fewer** segment bytes with zone maps on —
/// here, zero bytes, every segment window proven dead.
#[test]
fn pruned_store_queries_read_strictly_fewer_segment_bytes() {
    let k = 8usize;
    // Extreme clustered content: batch `b`'s records all carry the key
    // of attribute `b % m`, so each one-batch segment holds exactly one
    // nonzero row.
    let data: Vec<Vec<Vec<i32>>> =
        (0..k).map(|b| vec![vec![KEYS[b % KEYS.len()]; 4]; 16]).collect();
    let dir_on = tmpdir("prune-bytes-on");
    let dir_off = tmpdir("prune-bytes-off");
    let on = builder()
        .durable(&dir_on)
        .flush_batches(1) // every batch becomes a segment
        .build()
        .expect("build on");
    let off = builder()
        .durable(&dir_off)
        .flush_batches(1)
        .zone_maps(false)
        .build()
        .expect("build off");
    on.ingest_batches(&data).expect("ingest on");
    off.ingest_batches(&data).expect("ingest off");

    // Rows 0 and 1 never share a segment: provably empty conjunction.
    let q = Query::attr(0).and(Query::attr(1));
    assert_eq!(on.plan(&q).path, ExecPath::Store, "segments exist");
    let got_on = on.query(&q).expect("pruned query");
    let got_off = off.query(&q).expect("unpruned query");
    assert_eq!(got_on, got_off, "pruning is cost-only");
    assert!(got_on.is_zero(), "the bands are disjoint");

    let (s_on, s_off) = (on.stats(), off.stats());
    assert_eq!(
        s_on.store_row_bytes_read, 0,
        "every segment window was zone-skipped"
    );
    assert_eq!(s_on.store_chunks_skipped, k as u64);
    assert!(s_off.store_row_bytes_read > 0, "noskip engine reads rows");
    assert!(
        s_on.store_row_bytes_read < s_off.store_row_bytes_read,
        "strictly fewer segment bytes"
    );
    on.close().expect("close on");
    off.close().expect("close off");
    let _ = fs::remove_dir_all(&dir_on);
    let _ = fs::remove_dir_all(&dir_off);
}

/// Compaction merges must preserve zone maps: after foreground merges
/// rewrite the segments, a dead conjunction still skips every window.
#[test]
fn zone_maps_survive_compaction_merges() {
    let dir = tmpdir("zone-compact");
    let engine = builder()
        .durable(&dir)
        .flush_batches(1)
        .max_segments(2)
        .compaction(CompactionMode::Foreground)
        .build()
        .expect("build");
    // Batches alternate between the first two keys: rows 2..8 are zero
    // in every segment, merged or not.
    let data: Vec<Vec<Vec<i32>>> =
        (0..8).map(|b| vec![vec![KEYS[b % 2]; 4]; 16]).collect();
    engine.ingest_batches(&data).expect("ingest");
    let stats = engine.stats();
    assert!(stats.segments <= 2, "compaction ran");
    let q = Query::attr(0).and(Query::attr(2));
    let got = engine.query(&q).expect("query");
    assert!(got.is_zero());
    let stats = engine.stats();
    assert_eq!(stats.store_rows_folded, 0, "merged zone maps still skip");
    assert!(stats.store_chunks_skipped > 0);
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

/// Pipelined ingest: receipts resolve in batch-id order with the same
/// durability meaning as the synchronous path, and the resulting index
/// is bit-identical to the synchronous reference.
#[test]
fn async_ingest_receipts_drain_in_batch_order_and_match_sync() {
    let dir = tmpdir("async");
    let data = batches(ContentDist::Zipf { s: 1.2 }, 0xA51C, 9);
    let expect = reference(&data);
    let engine =
        builder().durable(&dir).flush_batches(4).build().expect("build");
    // Submit the whole trace before waiting on anything: the pipeline
    // overlaps encode with append and group-commits runs of batches.
    let tickets =
        engine.ingest_batches_async(data.clone()).expect("submit");
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().expect("receipt");
        assert_eq!(r.batch, i as u64, "receipts drain in batch-id order");
        assert!(r.durable, "durable engine acks through the WAL");
        assert_eq!(r.objects, CFG.n_records);
        assert_eq!(
            r.total_objects,
            (i + 1) * CFG.n_records,
            "appends happen in submission order"
        );
    }
    assert_eq!(engine.snapshot().to_index(), expect, "async == sync bits");
    for (qi, q) in query_corpus().iter().enumerate() {
        assert_eq!(
            engine.query(q).expect("query"),
            q.eval(&expect).expect("reference"),
            "async-built index query {qi}"
        );
    }
    let stats = engine.close().expect("close");
    assert_eq!(stats.batches_ingested, 9);

    // Reopen: everything the tickets acknowledged is durable.
    let engine =
        builder().durable(&dir).flush_batches(4).build().expect("reopen");
    assert_eq!(engine.snapshot().to_index(), expect, "recovered bits");
    engine.close().expect("close 2");
    let _ = fs::remove_dir_all(&dir);
}

/// `close` drains the pipeline: tickets never waited on still resolve,
/// and every submitted batch is applied before close returns.
#[test]
fn close_drains_the_async_pipeline() {
    let data = batches(ContentDist::Uniform, 0xD0A1, 6);
    let expect = reference(&data);
    let engine = builder().build().expect("build");
    let tickets =
        engine.ingest_batches_async(data.clone()).expect("submit");
    let stats = engine.close().expect("close");
    assert_eq!(stats.batches_ingested, 6, "close applied every batch");
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().expect("ticket resolved after close");
        assert_eq!(r.batch, i as u64);
        assert!(!r.durable, "in-memory engine never claims durability");
    }
    // And a fresh engine over the same data agrees bit-for-bit.
    let engine = builder().build().expect("rebuild");
    let mut tickets = Vec::new();
    for records in &data {
        tickets.push(engine.ingest_async(records.clone()).expect("submit"));
    }
    for t in tickets {
        t.wait().expect("receipt");
    }
    assert_eq!(engine.snapshot().to_index(), expect);
    engine.close().expect("close 2");
}

/// Async submission validates records synchronously, exactly like the
/// synchronous path.
#[test]
fn async_ingest_validates_before_queueing() {
    let engine = builder().build().expect("build");
    let too_many = vec![vec![1i32; 4]; CFG.n_records + 1];
    assert!(matches!(
        engine.ingest_async(too_many),
        Err(PallasError::Ingest(_))
    ));
    let too_wide = vec![vec![1i32; CFG.w_words + 1]];
    assert!(matches!(
        engine.ingest_batches_async(vec![too_wide]),
        Err(PallasError::Ingest(_))
    ));
    // A zero-depth queue is a construction-time config error.
    assert!(matches!(
        builder().ingest_queue(0).build(),
        Err(PallasError::Config(_))
    ));
    engine.close().expect("close");
}

#[test]
fn planner_prefers_the_store_tier_once_segments_exist() {
    let dir = tmpdir("planner");
    let engine =
        builder().durable(&dir).flush_batches(2).build().expect("build");
    let data = batches(ContentDist::Uniform, 0x99, 5);
    engine.ingest_batches(&data).expect("ingest");
    let q = Query::attr(0).and(Query::attr(1));
    assert_eq!(engine.plan(&q).path, ExecPath::Store);
    engine.query(&q).expect("query");
    let stats = engine.stats();
    assert_eq!(stats.queries_store, 1);
    assert_eq!(stats.queries_total(), 1);
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn stats_json_surface_is_versioned_and_stable() {
    use sotb_bic::substrate::json::Json;
    let dir = tmpdir("stats-json");
    let engine =
        builder().durable(&dir).flush_batches(2).build().expect("build");
    let data = batches(ContentDist::Uniform, 0x5a, 4);
    engine.ingest_batches(&data).expect("ingest");
    engine.query(&Query::attr(0)).expect("query");
    let stats = engine.stats();
    // Round-trip through render/parse: the wire form, not the tree.
    let doc = Json::parse(&stats.to_json().render()).expect("valid JSON");
    let num =
        |k: &str| doc.get(k).and_then(Json::as_f64).unwrap_or_else(|| {
            panic!("stats JSON missing numeric field {k:?}")
        });
    assert_eq!(num("stats_version"), 4.0);
    assert_eq!(num("attrs"), CFG.m_keys as f64);
    assert_eq!(num("batches_ingested"), 4.0);
    assert_eq!(num("objects"), stats.objects as f64);
    assert_eq!(num("segments"), stats.segments as f64);
    assert_eq!(num("queries_total"), stats.queries_total() as f64);
    assert_eq!(num("degraded_segments"), 0.0);
    assert_eq!(num("rows_unavailable"), 0.0);
    assert_eq!(num("store_chunks_skipped"), stats.store_chunks_skipped as f64);
    assert_eq!(doc.get("durable").and_then(Json::as_bool), Some(true));
    // Version 2 is additive: everything a v1 consumer parsed by name is
    // still present under the same name (the full v1 field list), and
    // the v2 additions sit alongside.
    for v1_field in [
        "stats_version",
        "attrs",
        "columns",
        "workers",
        "batches_ingested",
        "objects",
        "segments",
        "queries_total",
        "store_row_bytes_read",
        "store_chunks_skipped",
        "degraded_segments",
        "rows_unavailable",
        "durable",
    ] {
        assert!(doc.get(v1_field).is_some(), "v1 field {v1_field} vanished");
    }
    for v2_field in [
        "scrub_passes",
        "scrub_bytes_verified",
        "compaction_rounds",
        "compaction_bytes_written",
        "telemetry",
    ] {
        assert!(
            doc.get(v2_field).and_then(Json::as_f64).is_some()
                || doc.get(v2_field).and_then(Json::as_bool).is_some(),
            "v2 field {v2_field} missing"
        );
    }
    // Version 3 additions (bit-sliced tier) are additive the same way.
    for v3_field in ["queries_bsi", "aggregates", "topk_queries"] {
        assert!(
            doc.get(v3_field).and_then(Json::as_f64).is_some(),
            "v3 field {v3_field} missing"
        );
    }
    // Version 4 adds the surface's first non-numeric field: the active
    // kernel tier label. Still additive — numeric consumers skip it.
    assert!(
        matches!(
            doc.get("kernel_tier").and_then(Json::as_str),
            Some("scalar") | Some("avx2")
        ),
        "v4 field kernel_tier missing or unlabelled"
    );
    assert_eq!(doc.get("telemetry").and_then(Json::as_bool), Some(false));
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn builder_from_json_round_trips_every_knob() {
    use sotb_bic::substrate::json::Json;
    let doc = Json::parse(
        r#"{"batch_records":64,"record_words":8,"ingest_queue":2,
            "codec":"wah","shard":"never","exec":"compressed",
            "zone_maps":false,"degraded":"serve_healthy"}"#,
    )
    .expect("parse");
    let b = EngineBuilder::from_json(schema(), &doc).expect("from_json");
    assert_eq!(b.config().ingest_queue, 2);
    assert_eq!(b.config().codec, CodecPolicy::Forced(Codec::Wah));
    assert_eq!(b.config().shard, ShardPolicy::Never);
    assert_eq!(b.config().exec, ExecPolicy::Force(ExecPath::Compressed));
    assert!(!b.config().zone_maps);
    // The emitted form re-parses to the same config.
    let emitted = b.config().to_json();
    let again = EngineBuilder::from_json(schema(), &emitted).expect("again");
    assert_eq!(again.config().to_json().render(), emitted.render());
    // And the engine it builds works.
    let engine = b.build().expect("build");
    let data = batches(ContentDist::Clustered, 0x77, 2);
    engine.ingest_batches(&data).expect("ingest");
    assert_eq!(engine.query(&Query::attr(1)).expect("q"), {
        let r = reference(&data);
        r.row(1).clone()
    });
    // A misspelled knob is a typed config error, not a silent default.
    let bad = Json::parse(r#"{"ingset_queue":2}"#).expect("parse");
    assert!(matches!(
        EngineBuilder::from_json(schema(), &bad),
        Err(PallasError::Config(_))
    ));
    engine.close().expect("close");
}
