//! Integration properties of the multi-tenant service tier
//! (`sotb_bic::server`): wire-error shape, admission control (typed
//! `busy`, never a blocked socket), tenant isolation under concurrent
//! load, the `metrics` surface, restart recovery, and the connection
//! cap.
//!
//! Every test runs a real server on `127.0.0.1:0` and talks to it over
//! real sockets through [`Client`] — the same transport `bic_client`
//! and the contention bench use.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use sotb_bic::engine::{EngineConfig, Schema};
use sotb_bic::server::client::Client;
use sotb_bic::server::protocol::{response_error_code, response_ok};
use sotb_bic::server::{Server, ServerHandle};
use sotb_bic::store::vfs::{RealVfs, Vfs, VfsFile};
use sotb_bic::substrate::json::Json;

const KEYS: [i32; 4] = [1, 2, 3, 4];

fn tmproot(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bic-server-props-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn schema() -> Schema {
    Schema::single("k", KEYS).expect("schema")
}

fn schema_json() -> Json {
    Json::obj([(
        "columns",
        Json::Arr(vec![Json::obj([
            ("name", "k".into()),
            ("values", KEYS.to_vec().into()),
        ])]),
    )])
}

fn spawn_server(root: &Path, max_conns: usize) -> ServerHandle {
    Server::bind(root, "127.0.0.1:0", max_conns).expect("bind").spawn()
}

/// A batch of one-word records, all carrying `key`.
fn batch_of(key: i32, n: usize) -> Vec<Vec<i32>> {
    vec![vec![key]; n]
}

fn eq(key: i32) -> Json {
    Json::obj([("col", "k".into()), ("eq", key.into())])
}

fn count(resp: &Json) -> f64 {
    assert!(response_ok(resp), "query failed: {}", resp.render());
    resp.get("count").and_then(Json::as_f64).expect("count field")
}

/// Assert a failed response carries the full `{code, what, detail}`
/// error surface, and return the code.
fn assert_error_shape(resp: &Json, expect_code: &str) {
    assert!(!response_ok(resp), "expected failure: {}", resp.render());
    let err = resp.get("error").expect("error object");
    assert_eq!(
        err.get("code").and_then(Json::as_str),
        Some(expect_code),
        "code in {}",
        resp.render()
    );
    for field in ["what", "detail"] {
        let v = err.get(field).and_then(Json::as_str).unwrap_or_default();
        assert!(!v.is_empty(), "empty {field} in {}", resp.render());
    }
}

// ---------------------------------------------------------------------
// A VFS that can suspend WAL fsyncs: the deterministic way to wedge a
// tenant's appender stage so its bounded in-flight gate fills and
// `try_ingest_async` starts shedding.
// ---------------------------------------------------------------------

#[derive(Debug, Default)]
struct HoldGate {
    held: Mutex<bool>,
    cv: Condvar,
}

impl HoldGate {
    fn hold(&self) {
        *self.held.lock().expect("gate") = true;
    }

    fn release(&self) {
        *self.held.lock().expect("gate") = false;
        self.cv.notify_all();
    }

    fn wait_released(&self) {
        let mut g = self.held.lock().expect("gate");
        while *g {
            g = self.cv.wait(g).expect("gate");
        }
    }
}

/// Pass-through to [`RealVfs`], except that `sync` on WAL appenders
/// blocks while the gate is held.
#[derive(Debug)]
struct HoldVfs {
    inner: RealVfs,
    gate: Arc<HoldGate>,
}

struct HoldFile {
    inner: Box<dyn VfsFile>,
    gate: Option<Arc<HoldGate>>,
}

impl VfsFile for HoldFile {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        self.inner.write_all(buf)
    }

    fn sync(&mut self) -> std::io::Result<()> {
        if let Some(g) = &self.gate {
            g.wait_released();
        }
        self.inner.sync()
    }
}

impl Vfs for HoldVfs {
    fn create_dir_all(&self, dir: &Path) -> std::io::Result<()> {
        self.inner.create_dir_all(dir)
    }

    fn create(&self, path: &Path) -> std::io::Result<Box<dyn VfsFile>> {
        self.inner.create(path)
    }

    fn open_append(&self, path: &Path) -> std::io::Result<Box<dyn VfsFile>> {
        let is_wal = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.starts_with("wal-"));
        Ok(Box::new(HoldFile {
            inner: self.inner.open_append(path)?,
            gate: is_wal.then(|| Arc::clone(&self.gate)),
        }))
    }

    fn open_truncated(
        &self,
        path: &Path,
        len: u64,
    ) -> std::io::Result<Box<dyn VfsFile>> {
        self.inner.open_truncated(path, len)
    }

    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
        self.inner.read(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        self.inner.rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> std::io::Result<()> {
        self.inner.remove_file(path)
    }

    fn list(&self, dir: &Path) -> std::io::Result<Vec<String>> {
        self.inner.list(dir)
    }

    fn sync_dir(&self, dir: &Path) -> std::io::Result<()> {
        self.inner.sync_dir(dir)
    }
}

// ---------------------------------------------------------------------
// Wire-error surface
// ---------------------------------------------------------------------

/// Every failure on the wire is `{ok:false, error:{code, what,
/// detail}}` with the documented codes — including lines that never
/// parse into a request, which still get a full typed response instead
/// of a dropped connection.
#[test]
fn wire_errors_carry_code_what_detail() {
    let root = tmproot("errors");
    let handle = spawn_server(&root, 8);
    let mut c = Client::connect(handle.local_addr()).expect("connect");

    // Unknown tenant.
    let resp = c.query("ghost", &eq(1)).expect("transport");
    assert_error_shape(&resp, "unknown-tenant");
    // Structural problems: missing cmd, unknown cmd, bad tenant name.
    let resp =
        c.call(&Json::obj([("id", 9.into())])).expect("transport");
    assert_error_shape(&resp, "bad-request");
    assert_eq!(resp.get("id").and_then(Json::as_f64), Some(9.0), "id echo");
    let resp =
        c.call(&Json::obj([("cmd", "explode".into())])).expect("transport");
    assert_error_shape(&resp, "bad-request");
    let resp = c
        .create_tenant("no/slashes", &schema_json(), None)
        .expect("transport");
    assert_error_shape(&resp, "bad-request");

    // Engine-typed failures map through the single conversion point.
    let resp =
        c.create_tenant("t", &schema_json(), None).expect("transport");
    assert!(response_ok(&resp), "create: {}", resp.render());
    let resp = c.create_tenant("t", &schema_json(), None).expect("transport");
    assert_error_shape(&resp, "config"); // duplicate tenant
    let resp = c
        .ingest("t", &batch_of(1, 99), true)
        .expect("transport");
    assert_error_shape(&resp, "ingest"); // batch exceeds capacity
    let resp = c
        .query("t", &Json::obj([("col", "nope".into()), ("eq", 1.into())]))
        .expect("transport");
    assert_error_shape(&resp, "invalid-query");

    // Raw garbage on the socket: still one typed line back.
    let mut raw =
        TcpStream::connect(handle.local_addr()).expect("raw connect");
    raw.write_all(b"{this is not json\n").expect("write");
    let mut line = String::new();
    BufReader::new(raw.try_clone().expect("clone"))
        .read_line(&mut line)
        .expect("read");
    let resp = Json::parse(line.trim()).expect("valid json response");
    assert_error_shape(&resp, "bad-request");

    handle.stop();
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// Admission control
// ---------------------------------------------------------------------

/// A tenant whose WAL is wedged fills its 1-slot in-flight gate; the
/// next ingest gets a typed `busy` *immediately* on a connection that
/// stays fully usable, while an independent tenant keeps ingesting
/// durably. Releasing the WAL drains the gate and the tenant recovers.
#[test]
fn full_queue_sheds_busy_while_other_tenant_ingests() {
    let root = tmproot("busy");
    let handle = spawn_server(&root, 8);
    let gate = Arc::new(HoldGate::default());
    let cfg = EngineConfig {
        ingest_queue: 1,
        flush_batches: 0, // manual flush only: nothing else touches disk
        vfs: Arc::new(HoldVfs {
            inner: RealVfs,
            gate: Arc::clone(&gate),
        }),
        ..EngineConfig::default()
    };
    handle.create_tenant_with("a", schema(), cfg).expect("tenant a");
    let mut c = Client::connect(handle.local_addr()).expect("connect");
    let resp = c.create_tenant("b", &schema_json(), None).expect("transport");
    assert!(response_ok(&resp), "create b: {}", resp.render());

    gate.hold();
    // First async batch is admitted and occupies the only slot (its
    // receipt cannot be delivered while the WAL sync is held).
    let resp = c.ingest("a", &batch_of(1, 2), false).expect("transport");
    assert!(response_ok(&resp), "admit: {}", resp.render());
    assert_eq!(resp.get("queued").and_then(Json::as_bool), Some(true));
    // Second batch: typed busy, immediately — not a stalled socket, not
    // a dropped connection.
    let resp = c.ingest("a", &batch_of(2, 2), false).expect("transport");
    assert_error_shape(&resp, "busy");
    // The same connection still serves everything else.
    assert!(c.ping().expect("transport"), "connection wedged by busy");
    for _ in 0..3 {
        let resp = c.ingest("b", &batch_of(3, 2), true).expect("transport");
        assert!(response_ok(&resp), "tenant b: {}", resp.render());
        assert_eq!(
            resp.get("durable").and_then(Json::as_bool),
            Some(true),
            "b stays durable while a is wedged"
        );
    }
    // The shed is visible in a's server counters.
    let stats = c.stats("a").expect("transport");
    assert!(response_ok(&stats), "stats: {}", stats.render());
    let sheds = stats
        .get("server")
        .and_then(|s| s.get("busy_sheds"))
        .and_then(Json::as_f64)
        .expect("busy_sheds");
    assert!(sheds >= 1.0, "busy_sheds = {sheds}");

    gate.release();
    // The wedged batch drains; the tenant accepts ingest again (a short
    // busy tail is legal while the slot frees).
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let resp = c.ingest("a", &batch_of(1, 2), true).expect("transport");
        if response_ok(&resp) {
            break;
        }
        assert_eq!(response_error_code(&resp), Some("busy"));
        assert!(
            std::time::Instant::now() < deadline,
            "tenant a never recovered after release"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    // Both batches of key 1 (the queued one and the retried one) landed.
    let resp = c.query("a", &eq(1)).expect("transport");
    assert_eq!(count(&resp), 4.0);
    let resp = c.query("b", &eq(3)).expect("transport");
    assert_eq!(count(&resp), 6.0);

    handle.stop();
    let _ = std::fs::remove_dir_all(&root);
}

/// At the connection cap the accept loop sheds with one full typed
/// `busy` line and a clean close — the capped-out client never hangs.
#[test]
fn connection_cap_sheds_with_typed_busy_line() {
    let root = tmproot("cap");
    let handle = spawn_server(&root, 1);
    let mut first = Client::connect(handle.local_addr()).expect("first");
    assert!(first.ping().expect("transport"), "first connection serves");
    // The cap is taken; the next connection gets the busy line up
    // front, without sending anything.
    let second =
        TcpStream::connect(handle.local_addr()).expect("second connect");
    second
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("timeout");
    let mut line = String::new();
    BufReader::new(second).read_line(&mut line).expect("read busy line");
    let resp = Json::parse(line.trim()).expect("valid json");
    assert_error_shape(&resp, "busy");
    // The admitted connection was never perturbed.
    assert!(first.ping().expect("transport"));
    drop(first);
    // The slot frees; a later client is admitted normally.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let mut retry =
            Client::connect(handle.local_addr()).expect("reconnect");
        match retry.ping() {
            Ok(true) => break,
            _ => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "slot never freed"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
    handle.stop();
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// Tenant isolation
// ---------------------------------------------------------------------

/// Tenant a's maintenance (flush, compaction, scrub, close) never
/// perturbs tenant b: b ingests concurrently throughout and every
/// record lands exactly once.
#[test]
fn maintenance_on_one_tenant_never_perturbs_another() {
    let root = tmproot("isolation");
    let handle = spawn_server(&root, 8);
    let addr = handle.local_addr();
    let mut c = Client::connect(addr).expect("connect");
    // Aggressive maintenance on a: flush every 2 batches, compact in
    // the foreground whenever more than 2 segments are live.
    let acfg = Json::obj([
        ("flush_batches", 2.into()),
        ("max_segments", 2.into()),
        ("compaction", "foreground".into()),
    ]);
    let resp =
        c.create_tenant("a", &schema_json(), Some(&acfg)).expect("transport");
    assert!(response_ok(&resp), "create a: {}", resp.render());
    let resp = c.create_tenant("b", &schema_json(), None).expect("transport");
    assert!(response_ok(&resp), "create b: {}", resp.render());

    const B_BATCHES: usize = 40;
    let writer = std::thread::spawn(move || -> Result<(), String> {
        let mut w = Client::connect(addr).map_err(|e| e.to_string())?;
        for i in 0..B_BATCHES {
            let key = KEYS[i % KEYS.len()];
            let resp = w
                .ingest("b", &batch_of(key, 4), true)
                .map_err(|e| e.to_string())?;
            if !response_ok(&resp) {
                return Err(format!("b ingest {i}: {}", resp.render()));
            }
        }
        Ok(())
    });
    // Meanwhile: churn a through its whole maintenance surface.
    for round in 0..6 {
        let key = KEYS[round % KEYS.len()];
        let resp = c.ingest("a", &batch_of(key, 4), true).expect("transport");
        assert!(response_ok(&resp), "a ingest: {}", resp.render());
        let resp = c.flush("a").expect("transport");
        assert!(response_ok(&resp), "a flush: {}", resp.render());
        let resp = c.scrub("a").expect("transport");
        assert!(response_ok(&resp), "a scrub: {}", resp.render());
        assert_eq!(
            resp.get("quarantined").and_then(Json::as_arr).map(<[Json]>::len),
            Some(0),
            "a scrub quarantined segments"
        );
    }
    let resp = c.close_tenant("a").expect("transport");
    assert!(response_ok(&resp), "a close: {}", resp.render());
    writer.join().expect("writer thread").expect("b ingest clean");

    // b: every batch landed exactly once, none lost, none duplicated.
    let per_key = (B_BATCHES / KEYS.len() * 4) as f64;
    for key in KEYS {
        let resp = c.query("b", &eq(key)).expect("transport");
        assert_eq!(count(&resp), per_key, "b key {key}");
    }
    // a reopens lazily (close released it) with its own data intact —
    // 6 rounds of 4 records cycling keys 1..=4: keys 1,2 got 2 rounds.
    let resp = c.query("a", &eq(1)).expect("transport");
    assert_eq!(count(&resp), 8.0, "a key 1");
    let resp = c.query("a", &eq(4)).expect("transport");
    assert_eq!(count(&resp), 4.0, "a key 4");

    handle.stop();
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// Metrics + restart
// ---------------------------------------------------------------------

/// `metrics` is valid JSON with the versioned per-tenant engine stats
/// and server counters; tenants survive a full server restart (the
/// registry reopens them lazily from their on-disk declarations).
#[test]
fn metrics_surface_and_restart_reopen() {
    let root = tmproot("metrics");
    let handle = spawn_server(&root, 8);
    let addr = handle.local_addr();
    let mut c = Client::connect(addr).expect("connect");
    let resp = c.create_tenant("t", &schema_json(), None).expect("transport");
    assert!(response_ok(&resp), "create: {}", resp.render());
    for i in 0..5 {
        let resp = c
            .ingest("t", &batch_of(KEYS[i % KEYS.len()], 3), true)
            .expect("transport");
        assert!(response_ok(&resp), "ingest: {}", resp.render());
    }

    let m = c.metrics().expect("transport");
    assert!(response_ok(&m), "metrics: {}", m.render());
    // Version 4 (kernel tier): purely additive over version 3 — every
    // numeric field keeps its v3 name and meaning, the new
    // `kernel_tier`/`bic_kernel_tier` fields are strings a v3 reader
    // that ignores unknown keys never sees. Protocol note in
    // `server::protocol`.
    assert_eq!(
        m.get("stats_version").and_then(Json::as_f64),
        Some(4.0),
        "stats_version"
    );
    assert!(
        m.get("bic_kernel_tier").and_then(Json::as_str).is_some(),
        "metrics must carry the kernel tier"
    );
    let t = m
        .get("tenants")
        .and_then(|ts| ts.get("t"))
        .expect("tenant t in metrics");
    let engine = t.get("engine").expect("engine stats");
    // The versioned EngineStats fields, by their frozen wire names.
    for field in [
        "stats_version",
        "batches_ingested",
        "objects",
        "attrs",
        "queries_total",
        "segments",
        "durable",
        "kernel_tier",
    ] {
        assert!(
            engine.get(field).is_some(),
            "engine.{field} missing in {}",
            engine.render()
        );
    }
    assert_eq!(engine.get("batches_ingested").and_then(Json::as_f64), Some(5.0));
    let server = t.get("server").expect("server counters");
    assert!(
        server.get("requests").and_then(Json::as_f64).unwrap_or(0.0) >= 6.0,
        "requests counted: {}",
        server.render()
    );
    let global = m.get("server").expect("global server block");
    assert!(
        global.get("active_connections").and_then(Json::as_f64).is_some()
            && global.get("max_connections").and_then(Json::as_f64)
                == Some(8.0),
        "global counters: {}",
        global.render()
    );
    // The in-process dump (what the bench reads) matches the wire shape.
    let inproc = handle.metrics().expect("in-process metrics");
    assert!(inproc.get("tenants").and_then(|ts| ts.get("t")).is_some());

    // Kill the server, start a fresh one over the same root: the tenant
    // reopens lazily from TENANT.json and every record is still there.
    drop(c);
    handle.stop();
    let handle = spawn_server(&root, 8);
    let mut c = Client::connect(handle.local_addr()).expect("reconnect");
    let resp = c.query("t", &eq(KEYS[0])).expect("transport");
    // 5 batches cycling 4 keys: key 1 carried batches 0 and 4.
    assert_eq!(count(&resp), 6.0, "key 1 after restart");
    let resp = c.query("t", &eq(KEYS[1])).expect("transport");
    assert_eq!(count(&resp), 3.0, "key 2 after restart");
    // Unknown tenants still answer typed errors after restart.
    let resp = c.query("ghost", &eq(1)).expect("transport");
    assert_error_shape(&resp, "unknown-tenant");

    handle.stop();
    let _ = std::fs::remove_dir_all(&root);
}
