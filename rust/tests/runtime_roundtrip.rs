//! Integration: AOT artifact -> PJRT -> bitmap must equal the pure-Rust
//! golden model (`bic::BicCore`) word-for-word, for every shipped variant.
//!
//! Requires `make artifacts` (skips with a message otherwise — CI runs
//! through the Makefile so the artifacts always exist there).

use sotb_bic::bic::{conjunctive, BicConfig, BicCore, PAD};
use sotb_bic::runtime::{BicExecutable, Manifest, QueryExecutable, Runtime};
use sotb_bic::substrate::rng::Xoshiro256;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

fn random_batch(
    rng: &mut Xoshiro256,
    n: usize,
    w: usize,
    fill: f64,
) -> Vec<Vec<i32>> {
    // `fill` controls ragged records: each record has 1..=w real words.
    (0..n)
        .map(|_| {
            let len = 1 + rng.next_below(((w as f64 * fill) as u64).max(1)) as usize;
            (0..len.min(w)).map(|_| rng.next_below(256) as i32).collect()
        })
        .collect()
}

fn random_keys(rng: &mut Xoshiro256, m: usize) -> Vec<i32> {
    (0..m).map(|_| rng.next_below(256) as i32).collect()
}

#[test]
fn every_bic_variant_matches_golden_model() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    for variant in manifest.bic.iter().chain(manifest.twostep.iter()) {
        let exe = BicExecutable::load(&rt, variant)
            .unwrap_or_else(|e| panic!("loading {}: {e:?}", variant.name));
        let cfg = BicConfig {
            n_records: variant.n,
            w_words: variant.w,
            m_keys: variant.m,
        };
        let mut golden = BicCore::new(cfg);
        let mut rng = Xoshiro256::seeded(0xB1C0 + variant.n as u64);
        for round in 0..3 {
            let recs = random_batch(&mut rng, variant.n, variant.w, 1.0);
            let keys = random_keys(&mut rng, variant.m);
            let via_pjrt = exe.index(&recs, &keys).expect("PJRT index");
            let via_rust = golden.index(&recs, &keys);
            assert_eq!(
                via_pjrt, via_rust,
                "variant {} round {round}: artifact != golden model",
                variant.name
            );
        }
    }
}

#[test]
fn short_and_ragged_batches_agree() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let variant = manifest.find_bic("chip").expect("chip variant");
    let exe = BicExecutable::load(&rt, variant).unwrap();
    let mut golden = BicCore::new(BicConfig::CHIP);
    let mut rng = Xoshiro256::seeded(77);
    // Half-full batch of ragged records.
    let recs = random_batch(&mut rng, 7, 32, 0.4);
    let keys = random_keys(&mut rng, 8);
    assert_eq!(exe.index(&recs, &keys).unwrap(), golden.index(&recs, &keys));
}

#[test]
fn coalesced_variant_matches_per_batch_dispatch() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let co = manifest.find_coalesce("batch").expect("coalesce4 artifact");
    let single = manifest.find_bic("batch").expect("batch artifact");
    let exe_co = BicExecutable::load(&rt, co).unwrap();
    let exe_single = BicExecutable::load(&rt, single).unwrap();

    let mut rng = Xoshiro256::seeded(1234);
    let keys = random_keys(&mut rng, co.m);
    let batches: Vec<Vec<Vec<i32>>> =
        (0..co.b).map(|_| random_batch(&mut rng, co.n, co.w, 1.0)).collect();
    let batch_refs: Vec<&[Vec<i32>]> =
        batches.iter().map(|b| b.as_slice()).collect();

    let coalesced = exe_co.index_coalesced(&batch_refs, &keys).unwrap();
    for (i, batch) in batches.iter().enumerate() {
        let one = exe_single.index(batch, &keys).unwrap();
        assert_eq!(coalesced[i], one, "batch {i}");
    }
}

#[test]
fn query_artifact_matches_rust_engine() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let bic_v = manifest.find_bic("batch").unwrap();
    let q_v = manifest.find_query("batch").expect("query artifact");
    let exe = BicExecutable::load(&rt, bic_v).unwrap();
    let qexe = QueryExecutable::load(&rt, q_v).unwrap();

    let mut rng = Xoshiro256::seeded(99);
    let recs = random_batch(&mut rng, bic_v.n, bic_v.w, 1.0);
    let keys = random_keys(&mut rng, bic_v.m);
    let bi = exe.index(&recs, &keys).unwrap();

    for trial in 0..5 {
        let include: Vec<bool> = (0..q_v.m).map(|_| rng.chance(0.4)).collect();
        let exclude: Vec<bool> = (0..q_v.m).map(|_| rng.chance(0.3)).collect();
        let via_pjrt = qexe.eval(&bi, &include, &exclude).unwrap();
        let via_rust = conjunctive(&bi, &include, &exclude);
        // The artifact returns raw words over n bits (tail bits zero by
        // the index's invariant + exclude cannot set them).
        assert_eq!(
            via_pjrt,
            via_rust.to_packed_words(),
            "trial {trial}: query artifact != rust engine"
        );
    }
}

#[test]
fn rejects_invalid_inputs() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let variant = manifest.find_bic("chip").unwrap();
    let exe = BicExecutable::load(&rt, variant).unwrap();
    // Too many records.
    let too_many = vec![vec![0i32; 32]; 17];
    assert!(exe.index(&too_many, &[1, 2, 3, 4, 5, 6, 7, 8]).is_err());
    // Wrong key count.
    assert!(exe.index(&[vec![1]], &[1, 2]).is_err());
    // PAD as key.
    assert!(exe
        .index(&[vec![1]], &[PAD, 2, 3, 4, 5, 6, 7, 8])
        .is_err());
}
