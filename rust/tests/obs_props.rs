//! Observability properties (`sotb_bic::obs` + the engine/server
//! surfaces built on it):
//!
//! - the log-bucketed histogram's quantiles land in the *same bucket*
//!   as an exact sorted-reference nearest-rank percentile, across
//!   uniform, heavy-tailed, constant, and sub-linear-range inputs;
//! - snapshot merging is associative/commutative and indistinguishable
//!   from having recorded everything into one histogram;
//! - concurrent recording loses nothing (count/sum/max and every
//!   quantile match a sequential replay);
//! - `Engine::explain` is differential: the predicted zone-skip set and
//!   fold accounting equal what the measured run's counters say;
//! - telemetry channels populate end to end, and the whole wire surface
//!   (`metrics` quantiles, `explain`, `slowlog`, `trace`,
//!   `telemetry-off`) round-trips through a real server.

use std::fs;
use std::path::PathBuf;

use sotb_bic::engine::{col, Engine, EngineBuilder, Schema};
use sotb_bic::obs::hist::{bucket_index, Histogram};
use sotb_bic::obs::HistSnapshot;
use sotb_bic::server::client::Client;
use sotb_bic::server::protocol::{response_error_code, response_ok};
use sotb_bic::server::Server;
use sotb_bic::substrate::json::Json;
use sotb_bic::substrate::rng::Xoshiro256;

const KEYS: [i32; 8] = [2, 5, 11, 23, 77, 130, 200, 251];

fn schema() -> Schema {
    Schema::single("byte", KEYS).expect("schema")
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bic-obs-props-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A batch of `n` eight-word records, every word carrying `key` — the
/// most clusterable content possible: a segment built only from such
/// batches has exactly one nonzero attribute row, so zone maps prove
/// every other attribute absent.
fn single_key_batch(key: i32, n: usize) -> Vec<Vec<i32>> {
    vec![vec![key; 8]; n]
}

// ---------------------------------------------------------------------
// Histogram properties
// ---------------------------------------------------------------------

/// Exact 0-based nearest-rank percentile over a sorted slice — the
/// reference `HistSnapshot::quantile` is checked against.
fn exact_rank(sorted: &[u64], q: f64) -> u64 {
    let rank = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[rank]
}

fn quantile_grid() -> Vec<f64> {
    (0..=100).map(|i| i as f64 / 100.0).collect()
}

#[test]
fn quantiles_share_a_bucket_with_the_exact_reference() {
    let mut rng = Xoshiro256::seeded(0x0B5);
    let distributions: Vec<(&str, Vec<u64>)> = vec![
        ("uniform", (0..5_000).map(|_| rng.next_below(1_000_000)).collect()),
        (
            // Heavy tail: uniform mantissa under an exponentially
            // distributed magnitude, like real latency outliers.
            "log-uniform",
            (0..5_000)
                .map(|_| {
                    let mag = rng.next_below(30);
                    (1u64 << mag) + rng.next_below((1u64 << mag).max(1))
                })
                .collect(),
        ),
        ("constant", vec![4_242; 1_000]),
        // Entirely inside the exact sub-16 buckets.
        ("tiny", (0..2_000).map(|_| rng.next_below(16)).collect()),
        ("single", vec![7]),
    ];
    for (tag, values) in distributions {
        let h = Histogram::new();
        for &v in &values {
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, values.len() as u64, "{tag}: count");
        let mut sorted = values.clone();
        sorted.sort_unstable();
        assert_eq!(snap.max, *sorted.last().expect("nonempty"), "{tag}: max");
        for q in quantile_grid() {
            let exact = exact_rank(&sorted, q);
            let est = snap.quantile(q);
            // Same bucket: the estimate is the *upper bound* of the
            // bucket holding the exact nearest-rank sample...
            assert_eq!(
                bucket_index(est),
                bucket_index(exact),
                "{tag}: q={q} est={est} exact={exact}"
            );
            // ...so it never undershoots, and overshoots by at most the
            // bucket width (<= lo/8 + 1 by construction).
            assert!(est >= exact, "{tag}: q={q} est={est} < exact={exact}");
            assert!(
                est - exact <= exact / 8 + 1,
                "{tag}: q={q} est={est} too far above exact={exact}"
            );
        }
    }
}

#[test]
fn merge_is_associative_commutative_and_matches_single_recording() {
    let mut rng = Xoshiro256::seeded(0x3E6);
    let parts: Vec<Vec<u64>> = (0..3)
        .map(|p| {
            (0..1_500)
                .map(|_| rng.next_below(10u64.pow(p as u32 + 3)))
                .collect()
        })
        .collect();
    let snaps: Vec<HistSnapshot> = parts
        .iter()
        .map(|values| {
            let h = Histogram::new();
            for &v in values {
                h.record(v);
            }
            h.snapshot()
        })
        .collect();
    // (a + b) + c, a + (b + c), and (c + a) + b.
    let fold = |order: [usize; 3]| {
        let mut acc = snaps[order[0]].clone();
        acc.merge(&snaps[order[1]]);
        acc.merge(&snaps[order[2]]);
        acc
    };
    let everything = {
        let h = Histogram::new();
        for values in &parts {
            for &v in values {
                h.record(v);
            }
        }
        h.snapshot()
    };
    for order in [[0, 1, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]] {
        let m = fold(order);
        assert_eq!(m.count, everything.count, "{order:?}: count");
        assert_eq!(m.sum, everything.sum, "{order:?}: sum");
        assert_eq!(m.max, everything.max, "{order:?}: max");
        for q in quantile_grid() {
            assert_eq!(
                m.quantile(q),
                everything.quantile(q),
                "{order:?}: quantile({q})"
            );
        }
    }
}

#[test]
fn concurrent_recording_loses_nothing() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    let shared = Histogram::new();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let shared = &shared;
            s.spawn(move || {
                let mut rng = Xoshiro256::seeded(0xCC + t);
                for _ in 0..PER_THREAD {
                    shared.record(rng.next_below(1 << 20));
                }
            });
        }
    });
    let got = shared.snapshot();
    // Sequential replay with the same per-thread streams.
    let reference = Histogram::new();
    for t in 0..THREADS {
        let mut rng = Xoshiro256::seeded(0xCC + t);
        for _ in 0..PER_THREAD {
            reference.record(rng.next_below(1 << 20));
        }
    }
    let want = reference.snapshot();
    assert_eq!(got.count, THREADS * PER_THREAD);
    assert_eq!(got.count, want.count);
    assert_eq!(got.sum, want.sum);
    assert_eq!(got.max, want.max);
    for q in quantile_grid() {
        assert_eq!(got.quantile(q), want.quantile(q), "quantile({q})");
    }
}

// ---------------------------------------------------------------------
// EXPLAIN is differential
// ---------------------------------------------------------------------

/// Four flushed segments, the last two provably disjoint from the
/// predicate: explain's predicted skip set, the measured `analyze` run,
/// and the engine's own fold counters must all tell the same story.
#[test]
fn explain_predictions_match_the_measured_run() {
    let dir = tmpdir("explain");
    let engine = Engine::builder(schema())
        .batch_records(64)
        .record_words(8)
        .durable(&dir)
        .flush_batches(2)
        .build()
        .expect("build");
    // Segments 1+2 hold only KEYS[0]; segments 3+4 only KEYS[1].
    for _ in 0..4 {
        engine.ingest(&single_key_batch(KEYS[0], 64)).expect("ingest");
    }
    for _ in 0..4 {
        engine.ingest(&single_key_batch(KEYS[1], 64)).expect("ingest");
    }
    let p = col("byte").eq(KEYS[0]);
    let before = engine.stats();
    let report = engine.explain(&p, true).expect("explain");
    let after = engine.stats();

    // The reported tier is the planner's live decision, and exactly one
    // rule of the walk fired.
    let q = p.lower(&schema()).expect("lower");
    assert_eq!(report.tier, engine.plan(&q).path.label());
    assert_eq!(report.tier, "store", "durable segments plan to the store");
    assert!(!report.rules.is_empty());
    assert_eq!(
        report.rules.iter().filter(|r| r.matched).count(),
        1,
        "first-match-wins rule walk"
    );
    assert!(report.est_cost > 0);

    // Chunk verdicts: four zoned segments, the KEYS[1] half predicted
    // skipped without reading a row.
    let segments: Vec<_> =
        report.chunks.iter().filter(|c| c.kind == "segment").collect();
    assert_eq!(segments.len(), 4, "four flushed segments");
    for c in &segments {
        assert!(c.zoned, "segment at base {} lost its zone map", c.base);
        assert_eq!(c.nbits, 128, "two batches of 64 per segment");
        let holds_other_key = c.base >= 256;
        assert_eq!(
            c.skip, holds_other_key,
            "segment at base {}: skip verdict",
            c.base
        );
        if c.skip {
            assert_eq!(c.rows_folded, 0);
            assert_eq!(c.row_bytes, 0);
            assert!(c.windows_skipped > 0);
        } else {
            assert!(c.rows_folded > 0);
        }
    }

    // Differential core: prediction == measured run == engine counters.
    let actual = report.actual.as_ref().expect("analyze ran");
    assert_eq!(actual.stats, report.predicted, "predicted != measured");
    assert!(report.predicted.chunks_skipped > 0, "nothing was skippable");
    assert_eq!(
        after.store_chunks_skipped - before.store_chunks_skipped,
        report.predicted.chunks_skipped,
        "engine skip counter disagrees with the predicted skip set"
    );
    assert_eq!(
        after.store_row_bytes_read - before.store_row_bytes_read,
        report.predicted.row_bytes,
        "engine byte counter disagrees with the predicted fold"
    );
    // Every record carries KEYS[0] in the first half: 4 batches x 64.
    assert_eq!(actual.count, 256);

    // Without analyze the prediction half is identical and nothing runs.
    let quiet = engine.explain(&p, false).expect("explain");
    assert!(quiet.actual.is_none());
    assert_eq!(quiet.predicted, report.predicted);
    assert_eq!(engine.stats().queries_total(), after.queries_total());

    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Telemetry channels end to end
// ---------------------------------------------------------------------

#[test]
fn telemetry_channels_populate_and_the_ring_drains_incrementally() {
    let dir = tmpdir("channels");
    let engine = Engine::builder(schema())
        .batch_records(64)
        .record_words(8)
        .durable(&dir)
        .flush_batches(2)
        .telemetry(true)
        .build()
        .expect("build");
    for i in 0..4 {
        engine
            .ingest(&single_key_batch(KEYS[i % KEYS.len()], 64))
            .expect("ingest");
    }
    let p = col("byte").eq(KEYS[0]);
    for _ in 0..3 {
        engine.select(&p).expect("query");
    }
    engine.flush().expect("flush");
    engine.scrub().expect("scrub");

    let t = engine.telemetry().expect("telemetry on");
    assert!(t.ingest_ack.count() >= 4, "one ack per sync batch");
    assert!(t.wal_fsync.count() > 0, "durable ingest fsynced");
    let queries: u64 = t.query.iter().map(Histogram::count).sum();
    assert_eq!(queries, 3, "one per-tier sample per query");
    assert_eq!(t.query_bytes.count(), 3);
    assert!(t.flush.count() > 0, "flush duration recorded");
    assert!(t.scrub.count() > 0, "scrub duration recorded");
    assert!(engine.stats().telemetry);

    // The slow log saw the queries (default threshold admits all).
    let slow = engine.slowlog_json().expect("slowlog on");
    assert_eq!(slow.as_arr().map(<[Json]>::len), Some(3));

    // Draining the ring returns events once: a second drain with no
    // traffic in between is empty, and traffic after a drain shows up
    // in the next one.
    let first = engine.trace_json().expect("trace on");
    assert!(
        first.as_arr().is_some_and(|e| !e.is_empty()),
        "stage events published"
    );
    let second = engine.trace_json().expect("trace on");
    assert_eq!(second.as_arr().map(<[Json]>::len), Some(0));
    engine.select(&p).expect("query");
    let third = engine.trace_json().expect("trace on");
    assert!(third.as_arr().is_some_and(|e| !e.is_empty()));

    // The exposition JSON mirrors the channels.
    let doc = engine.telemetry_json().expect("exposition");
    let ack_count = doc
        .get("ingest_ack")
        .and_then(|h| h.get("count"))
        .and_then(Json::as_f64)
        .expect("ingest_ack.count");
    assert!(ack_count >= 4.0);
    assert!(
        doc.get("query").and_then(|q| q.get("store")).is_some(),
        "per-tier query histograms keyed by label"
    );
    engine.close().expect("close");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn disabled_telemetry_is_absent_not_empty() {
    let engine = EngineBuilder::new(schema())
        .batch_records(64)
        .record_words(8)
        .build()
        .expect("build");
    engine.ingest(&single_key_batch(KEYS[0], 64)).expect("ingest");
    engine.select(&col("byte").eq(KEYS[0])).expect("query");
    assert!(engine.telemetry().is_none());
    assert!(engine.telemetry_json().is_none());
    assert!(engine.trace_json().is_none());
    assert!(engine.slowlog_json().is_none());
    assert!(!engine.stats().telemetry);
    // Explain stays available: it reads plans and zone maps, not
    // telemetry.
    let report =
        engine.explain(&col("byte").eq(KEYS[0]), false).expect("explain");
    assert!(!report.rules.is_empty());
}

// ---------------------------------------------------------------------
// The wire surface
// ---------------------------------------------------------------------

#[test]
fn wire_surface_exposes_quantiles_explain_slowlog_and_trace() {
    let root = std::env::temp_dir()
        .join(format!("bic-obs-wire-{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    let handle =
        Server::bind(&root, "127.0.0.1:0", 8).expect("bind").spawn();
    let mut c = Client::connect(handle.local_addr()).expect("connect");

    let schema_doc = Json::obj([(
        "columns",
        Json::Arr(vec![Json::obj([
            ("name", "k".into()),
            ("values", vec![1, 2, 3, 4].into()),
        ])]),
    )]);
    let telem_cfg = Json::obj([
        ("telemetry", true.into()),
        ("flush_batches", 2.into()),
    ]);
    for (name, cfg) in [("obs", Some(&telem_cfg)), ("plain", None)] {
        let resp =
            c.create_tenant(name, &schema_doc, cfg).expect("transport");
        assert!(response_ok(&resp), "create {name}: {}", resp.render());
    }
    let eq1 = Json::obj([("col", "k".into()), ("eq", 1.into())]);
    for _ in 0..6 {
        let resp = c
            .ingest("obs", &vec![vec![1i32]; 8], true)
            .expect("transport");
        assert!(response_ok(&resp), "ingest: {}", resp.render());
        let resp = c.query("obs", &eq1).expect("transport");
        assert!(response_ok(&resp), "query: {}", resp.render());
    }
    let resp = c.scrub("obs").expect("transport");
    assert!(response_ok(&resp), "scrub: {}", resp.render());

    // metrics: versioned, with per-tenant quantiles for the telemetry
    // tenant only, maintenance counters exposed, and the Prometheus
    // text alongside.
    let m = c.metrics().expect("transport");
    assert!(response_ok(&m), "metrics: {}", m.render());
    assert_eq!(m.get("stats_version").and_then(Json::as_f64), Some(4.0));
    assert!(
        matches!(
            m.get("bic_kernel_tier").and_then(Json::as_str),
            Some("scalar") | Some("avx2")
        ),
        "metrics must name the active kernel tier"
    );
    let obs_tenant =
        m.get("tenants").and_then(|t| t.get("obs")).expect("tenant obs");
    let telem = obs_tenant.get("telemetry").expect("telemetry section");
    let ack = telem.get("ingest_ack").expect("ingest_ack channel");
    for field in ["count", "p50", "p90", "p99"] {
        assert!(
            ack.get(field).and_then(Json::as_f64).expect(field) > 0.0,
            "ingest_ack.{field} not populated: {}",
            ack.render()
        );
    }
    let engine_stats = obs_tenant.get("engine").expect("engine stats");
    assert!(
        engine_stats
            .get("scrub_passes")
            .and_then(Json::as_f64)
            .expect("scrub_passes exposed")
            >= 1.0,
        "scrub counter lost between store and metrics"
    );
    assert_eq!(
        engine_stats.get("telemetry").and_then(Json::as_bool),
        Some(true)
    );
    let plain_tenant =
        m.get("tenants").and_then(|t| t.get("plain")).expect("tenant plain");
    assert!(
        plain_tenant.get("telemetry").is_none(),
        "non-collecting tenant must not fake a telemetry section"
    );
    let prom = m
        .get("prometheus")
        .and_then(Json::as_str)
        .expect("prometheus text");
    assert!(prom.starts_with("# bic_metrics_version 4"), "version header");
    for series in [
        "bic_ingest_ack_cycles",
        "bic_query_cycles",
        "tenant=\"obs\"",
        "bic_kernel_tier{tier=\"",
    ] {
        assert!(prom.contains(series), "prometheus lacks {series}");
    }

    // explain round-trips over the wire, tier + analyze attached.
    let resp = c.explain("obs", &eq1, true).expect("transport");
    assert!(response_ok(&resp), "explain: {}", resp.render());
    let report = resp.get("explain").expect("report");
    assert!(report.get("tier").and_then(Json::as_str).is_some());
    assert!(report.get("kernel_tier").and_then(Json::as_str).is_some());
    assert!(report.get("rules").and_then(Json::as_arr).is_some());
    assert!(report.get("actual").is_some(), "analyze:true ran");
    // ...and works on the non-telemetry tenant too.
    let resp = c.explain("plain", &eq1, false).expect("transport");
    assert!(response_ok(&resp), "explain plain: {}", resp.render());

    // slowlog + trace answer on the collecting tenant...
    let resp = c.slowlog("obs").expect("transport");
    assert!(response_ok(&resp), "slowlog: {}", resp.render());
    assert!(resp
        .get("slowlog")
        .and_then(Json::as_arr)
        .is_some_and(|e| !e.is_empty()));
    let resp = c.trace("obs").expect("transport");
    assert!(response_ok(&resp), "trace: {}", resp.render());
    assert!(resp.get("events").and_then(Json::as_arr).is_some());

    // ...and are a typed `telemetry-off` error on the plain tenant.
    for resp in [
        c.slowlog("plain").expect("transport"),
        c.trace("plain").expect("transport"),
    ] {
        assert!(!response_ok(&resp), "expected failure: {}", resp.render());
        assert_eq!(
            response_error_code(&resp),
            Some("telemetry-off"),
            "in {}",
            resp.render()
        );
    }

    handle.stop();
    let _ = fs::remove_dir_all(&root);
}
